"""Async bucket executor: place -> dispatch -> (only then) block -> assemble.

Design points, each mapped to a paper/ROADMAP concern:

* **Compiled-solver cache.**  One jitted ``vmap``-ed solver per
  (solver, bucket size, dtype, warm?, opts) key, shared process-wide — a
  lambda path, a benchmark sweep, and every concurrent serving request reuse
  the same executables.  lam is a TRACED per-block vector, so neither a new
  lambda nor a coalesced batch with mixed lambdas recompiles.  Hits/misses are
  counted (``executor.compiled_hit`` / ``executor.compiled_miss``).

* **Async dispatch.**  JAX dispatch is asynchronous; the executor submits
  every bucket of a plan (LPT-placed across local devices when there are
  several — ``schedule.lpt_assign`` with the b^3 cost model, the paper's
  footnote-4 clubbing) and only synchronizes at assembly
  (``jax.block_until_ready`` on the batch of results).  Serial host loops
  around one-bucket-at-a-time ``np.asarray`` calls are gone.

* **Warm-start donation.**  W0 stacks are donated to the solver call on
  backends that support buffer donation (TPU/GPU), so a lambda path does not
  hold two copies of the largest bucket's iterate.

* **Structure-routed solver ladder.**  Each bucket carries the structure
  class the planner assigned (``engine.structure``); ``registry.route_for``
  maps it to a route: "closed_form" (pair/tree — the batched Pallas forest
  kernel plus an in-jit KKT check), "chordal" (host clique-tree direct
  solve), or "iterative" (the configured bcd/pg/admm solver).  Non-iterative
  routes are VERIFIED: the closed forms satisfy the edge KKT by
  construction, but non-edge dual feasibility can fail on adversarial
  matrices, so blocks whose residual exceeds ``route_check_tol`` are
  re-dispatched to the iterative solver (``router.fallback.*`` counters).
  Routing changes cost, never the answer.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core.instrument import bump, counts
from repro.core.schedule import lpt_assign
from repro.core.solvers import SOLVERS, WARM_START_SOLVERS
from repro.core.solvers.closed_form import (
    glasso_chordal_host,
    glasso_forest_stack,
    kkt_ok_stack,
    kkt_residual_host,
)

_CACHE_LOCK = threading.Lock()
_COMPILED: dict[tuple, Any] = {}


def _donate_supported() -> bool:
    return jax.default_backend() not in ("cpu",)


def _validate_solver_opts(solver: str, opts: dict) -> None:
    """Reject unknown solver kwargs up front — inside jit/vmap they surface
    as an opaque TypeError at the first bucket dispatch."""
    import inspect

    try:
        params = inspect.signature(SOLVERS[solver]).parameters
    except (TypeError, ValueError):  # jit wrapper without a signature
        return
    accepted = {
        n for n, p in params.items()
        if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
    } - {"S", "lam"}
    unknown = sorted(set(opts) - accepted)
    if unknown:
        raise TypeError(
            f"solver {solver!r} does not accept option(s) {unknown}; "
            f"accepted: {sorted(accepted)}"
        )


def compiled_bucket_solver(
    solver: str, size: int, dtype, *, warm: bool, opts_key: tuple = ()
):
    """Fetch-or-build the jitted batched solver for one bucket shape family.

    Signature of the returned callable:
        fn(blocks[n,size,size], lams[n])            when warm=False
        fn(blocks[n,size,size], lams[n], W0[n,...]) when warm=True (W0 donated
                                                    off-CPU)
    """
    key = (solver, int(size), jnp.dtype(dtype).name, bool(warm), opts_key)
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")
        solver_fn = SOLVERS[solver]
        opts = dict(opts_key)
        if warm:

            def run(blocks, lams, W0):
                return jax.vmap(
                    lambda Sb, lm, w0: solver_fn(Sb, lm, W0=w0, **opts)
                )(blocks, lams, W0)

            fn = jax.jit(run, donate_argnums=(2,) if _donate_supported() else ())
        else:

            def run(blocks, lams):
                return jax.vmap(lambda Sb, lm: solver_fn(Sb, lm, **opts))(
                    blocks, lams
                )

            fn = jax.jit(run)
        _COMPILED[key] = fn
        return fn


def compiled_closed_form(size: int, dtype, *, tol: float, verify: bool = True):
    """Fetch-or-build the jitted batched closed-form forest solver + verifier.

    Returned callable: fn(blocks[n,size,size], lams[n]) -> (Theta[n,...],
    ok[n]) where ok certifies the KKT residual within tol (scaled by max|S|).
    ``verify=False`` skips the batched-inverse check and returns ok=True —
    sound ONLY for the "pair" class, where the closed form has no non-edge
    dual constraints to violate (a 2x2 support is complete), so it is exact
    by construction.  Shares the process-global compiled cache with the
    iterative solvers, so serving, paths, and benchmarks reuse one
    executable per (size, dtype)."""
    key = (
        "__closed_form__", int(size), jnp.dtype(dtype).name, float(tol), verify
    )
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")

        def run(blocks, lams):
            thetas = glasso_forest_stack(blocks, lams)
            if verify:
                ok = kkt_ok_stack(blocks, lams, thetas, tol=tol)
            else:
                ok = jnp.ones(blocks.shape[0], dtype=bool)
            return thetas, ok

        fn = jax.jit(run)
        _COMPILED[key] = fn
        return fn


def dispatch_repair(
    solver: str,
    dtype,
    opts_key: tuple,
    size: int,
    blocks: np.ndarray,
    lams: np.ndarray,
    candidates,
):
    """Async re-dispatch of rejected fast-path blocks to the iterative tail.

    Shared by the executor and the serving batcher so repairs behave
    identically everywhere: the rejected candidate is PD (the KKT check
    treats non-PD as an infinite residual), just dual-infeasible — so its
    inverse is an excellent W iterate to warm-start from, typically cutting
    the repair to a few sweeps.  ``lams`` is per-block (serving repairs can
    mix lambdas)."""
    sub = jnp.asarray(np.asarray(blocks), dtype)
    lams_d = jnp.asarray(np.asarray(lams), dtype)
    warm = solver in WARM_START_SOLVERS
    W0 = None
    if warm:
        W0 = jnp.linalg.inv(jnp.asarray(np.asarray(candidates), dtype))
        # a candidate can be rejected BECAUSE it is singular: those rows
        # get the cold start W = S + lam*I instead of a NaN iterate
        finite = jnp.all(jnp.isfinite(W0), axis=(1, 2), keepdims=True)
        cold = sub + lams_d[:, None, None] * jnp.eye(size, dtype=dtype)
        W0 = jnp.where(finite, W0, cold)
    fn = compiled_bucket_solver(solver, size, dtype, warm=warm, opts_key=opts_key)
    bump("executor.dispatches")
    return fn(sub, lams_d, W0) if warm else fn(sub, lams_d)


def solve_chordal_bucket(
    bucket: blocks_mod.Bucket, lams: np.ndarray, *, tol: float
) -> tuple[np.ndarray, np.ndarray]:
    """Host clique-tree direct solve of one chordal bucket.

    Returns (padded Theta stack, per-block ok).  Cost is sum |C|^3 over
    maximal cliques per block — the chordal analog of the zero-fill sparse
    Cholesky — versus hundreds of O(size^3) iterations on the iterative
    path.  Verification failures are left to the caller's fallback."""
    n = bucket.blocks.shape[0]
    thetas = np.empty_like(np.asarray(bucket.blocks))
    ok = np.zeros(n, dtype=bool)
    for i, comp in enumerate(bucket.comps):
        b = len(comp)
        lam = float(lams[i])
        blk = np.asarray(bucket.blocks[i][:b, :b])
        padded = np.eye(bucket.size, dtype=thetas.dtype) / (1.0 + lam)
        try:
            theta = glasso_chordal_host(blk, lam)
            res = kkt_residual_host(blk, lam, theta)
            scale = max(1.0, float(np.abs(blk).max()))
            ok[i] = res <= tol * scale
            padded[:b, :b] = theta
        except (ValueError, np.linalg.LinAlgError):
            ok[i] = False
        thetas[i] = padded
    return thetas, ok


def compiled_cache_stats() -> dict[str, int]:
    return {
        "entries": len(_COMPILED),
        "hits": counts().get("executor.compiled_hit", 0),
        "misses": counts().get("executor.compiled_miss", 0),
    }


@dataclass
class _Pending:
    bucket: blocks_mod.Bucket
    out: Any                       # jax array (device routes) or np (chordal)
    ok: Any = None                 # per-block KKT flags for verified routes
    stacked: Any = None            # device input stack (reuse cache)
    key: tuple = ()
    repair: Any = None             # (row idx, in-flight iterative re-solve)


@dataclass
class BucketExecutor:
    """Solves plans; owns the per-path warm-start state.

    One instance per logical stream of related solves (a ``glasso`` call, a
    ``glasso_path``, one serving batch); the compiled cache underneath is
    global."""

    solver: str = "bcd"
    dtype: Any = jnp.float64
    solver_opts: dict = field(default_factory=dict)
    devices: list | None = None
    route: bool = True             # structure-routed ladder; False = PR-1 path
    route_check_tol: float = 1e-6  # KKT acceptance for closed-form candidates
    # bucket_key -> previous padded solution / input stacks (device arrays):
    # reused buckets warm-start from their own previous solution and skip the
    # host->device re-upload of their bit-identical padded blocks.
    _prev_solutions: dict = field(default_factory=dict)
    _prev_blocks: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.solver not in SOLVERS:
            raise ValueError(
                f"unknown solver {self.solver!r}; available: {sorted(SOLVERS)}"
            )
        _validate_solver_opts(self.solver, self.solver_opts)
        if self.devices is None:
            self.devices = list(jax.local_devices())
        self._opts_key = tuple(sorted(self.solver_opts.items()))

    # -- placement ---------------------------------------------------------

    def _place(self, buckets: list[blocks_mod.Bucket]) -> list:
        """LPT assignment of buckets to local devices (b^3 * n_blocks cost)."""
        if len(self.devices) <= 1 or not buckets:
            return [None] * len(buckets)
        cost = [b.blocks.shape[0] * float(b.size) ** 3 for b in buckets]
        assign = lpt_assign(cost, len(self.devices), cost=float)
        return [self.devices[w] for w in assign.worker_of]

    # -- warm starts -------------------------------------------------------

    def _warm_stack(
        self, bucket: blocks_mod.Bucket, key, lam: float, warm_W: np.ndarray | None
    ):
        """W0 stack for one bucket, or None.

        Reused bucket with a cached previous solution: W0 = inv(prev Theta)
        batched on device (the padded block of Theta is blkdiag, so its
        inverse's padded diagonal is finite; it is then reset to 1+lam).
        Otherwise fall back to gathering from the dense warm_W (merged
        components: block-diagonal of the old sub-components, valid PD warm
        start by Theorem 2)."""
        prev = self._prev_solutions.get(key)
        if prev is not None:
            W0 = jnp.linalg.inv(prev)
        elif warm_W is not None:
            stacks = []
            for c in bucket.comps:
                blk = warm_W[np.ix_(c, c)].astype(np.dtype(jnp.dtype(self.dtype).name))
                stacks.append(blocks_mod.pad_block(blk, bucket.size))
            W0 = jnp.asarray(np.stack(stacks), self.dtype)
        else:
            return None
        # padded diagonal of a W iterate must be 1 + lam (diagonal KKT)
        idx = jnp.arange(bucket.size)
        pad_mask = jnp.stack(
            [idx >= len(c) for c in bucket.comps]
        )  # (n, size) True on padded coords
        eye = jnp.eye(bucket.size, dtype=bool)
        fix = pad_mask[:, :, None] & eye[None, :, :]
        W0 = jnp.where(fix, jnp.asarray(1.0 + lam, W0.dtype), W0)
        off = pad_mask[:, :, None] ^ pad_mask[:, None, :]
        return jnp.where(off, jnp.zeros((), W0.dtype), W0)

    # -- solve -------------------------------------------------------------

    def solve_plan(
        self,
        plan: blocks_mod.Plan,
        lam: float,
        S: np.ndarray,
        *,
        warm_W: np.ndarray | None = None,
        reused_keys: frozenset = frozenset(),
        keep_solutions: bool = False,
    ) -> np.ndarray:
        """Dispatch all buckets, then assemble the dense Theta.

        ``reused_keys`` marks buckets whose padded arrays were carried over by
        the planner; their previous solutions (if retained via
        ``keep_solutions``) seed the warm start without touching the host.

        Routing ladder: buckets take the route their structure class maps to
        (``registry.route_for``), every non-iterative candidate is
        KKT-verified, and failures are re-dispatched to the iterative solver
        before assembly — see ``_verify_and_fallback``."""
        from repro.engine.planner import bucket_key  # local: avoid cycle at import
        from repro.engine.registry import route_for  # local: avoid cycle at import

        if self.route and len(plan.isolated):
            bump("router.route.singleton", int(len(plan.isolated)))
        placements = self._place(plan.buckets)
        pending: list[_Pending] = []
        for bucket, device in zip(plan.buckets, placements):
            key = bucket_key(bucket)
            n = bucket.blocks.shape[0]
            route = route_for(bucket.structure) if self.route else "iterative"
            if self.route:
                bump(f"router.route.{bucket.structure}", n)
            if route == "chordal":
                # host direct solve: no device round-trip for the candidate.
                # KKT failures are known IMMEDIATELY (host), so their repair
                # dispatches into the same async wave as everything else
                # instead of serializing after the barrier.
                out, ok = solve_chordal_bucket(
                    bucket, np.full(n, lam), tol=self.route_check_tol
                )
                p = _Pending(bucket=bucket, out=out, ok=None, key=key)
                if not ok.all():
                    idx = np.flatnonzero(~ok)
                    bump(f"router.fallback.{bucket.structure}", int(idx.size))
                    p.repair = self._dispatch_repair(bucket, idx, out[idx], lam)
                pending.append(p)
                continue
            stacked = self._prev_blocks.get(key) if key in reused_keys else None
            if stacked is None:
                stacked = jnp.asarray(bucket.blocks, self.dtype)
                if device is not None:
                    stacked = jax.device_put(stacked, device)
            elif device is not None and list(stacked.devices()) != [device]:
                # LPT may move a reused bucket between lambdas; a D2D copy
                # still beats re-uploading from host
                stacked = jax.device_put(stacked, device)
            lams = jnp.full((n,), lam, self.dtype)
            if device is not None:
                lams = jax.device_put(lams, device)
            if route == "closed_form":
                fn = compiled_closed_form(
                    bucket.size,
                    self.dtype,
                    tol=self.route_check_tol,
                    verify=bucket.structure != "pair",
                )
                theta, ok = fn(stacked, lams)
                bump("executor.dispatches")
                pending.append(
                    _Pending(bucket=bucket, out=theta, ok=ok, stacked=stacked, key=key)
                )
                continue
            if self.solver in WARM_START_SOLVERS:
                use_key = key if key in reused_keys else None
                W0 = self._warm_stack(bucket, use_key, lam, warm_W)
            else:
                W0 = None  # solver discards W0: skip the batched inversions
            if device is not None and W0 is not None:
                W0 = jax.device_put(W0, device)
            fn = compiled_bucket_solver(
                self.solver,
                bucket.size,
                self.dtype,
                warm=W0 is not None,
                opts_key=self._opts_key,
            )
            out = fn(stacked, lams, W0) if W0 is not None else fn(stacked, lams)
            bump("executor.dispatches")
            pending.append(_Pending(bucket=bucket, out=out, stacked=stacked, key=key))

        # single synchronization point: everything above was async dispatch
        jax.block_until_ready(
            [p.out for p in pending if isinstance(p.out, jax.Array)]
            + [p.repair[1] for p in pending if p.repair is not None]
        )
        for p in pending:
            if p.repair is not None:
                idx, fixed = p.repair
                p.out = np.array(p.out)
                p.out[idx] = np.asarray(fixed)
        self._verify_and_fallback(pending, lam)

        new_solutions: dict = {}
        new_blocks: dict = {}
        if keep_solutions:
            for p in pending:
                new_solutions[p.key] = p.out
                if p.stacked is not None:
                    new_blocks[p.key] = p.stacked
        self._prev_solutions = new_solutions
        self._prev_blocks = new_blocks
        return blocks_mod.assemble_dense(plan, [np.asarray(p.out) for p in pending], S)

    def _dispatch_repair(
        self, bucket: blocks_mod.Bucket, idx: np.ndarray, candidates, lam: float
    ):
        """Bucket-shaped wrapper over the shared ``dispatch_repair``."""
        out = dispatch_repair(
            self.solver,
            self.dtype,
            self._opts_key,
            bucket.size,
            np.asarray(bucket.blocks)[idx],
            np.full(int(idx.size), lam),
            candidates,
        )
        return (idx, out)

    def _verify_and_fallback(self, pending: list[_Pending], lam: float) -> None:
        """Re-dispatch every closed-form block whose KKT check failed to the
        iterative solver (the ladder's tail) and splice the repaired rows
        into the pending stacks.  Rare by design — the fast-path classes
        satisfy the KKT by construction except for non-edge dual feasibility
        on adversarial matrices — but this is what makes routing SAFE."""
        repairs = []
        for p in pending:
            if p.ok is None:
                continue
            ok = np.asarray(p.ok)
            if ok.all():
                continue
            idx = np.flatnonzero(~ok)
            bump(f"router.fallback.{p.bucket.structure}", int(idx.size))
            repairs.append((p, self._dispatch_repair(p.bucket, idx, np.asarray(p.out)[idx], lam)))
        if not repairs:
            return
        jax.block_until_ready([r[1][1] for r in repairs])
        for p, (idx, fixed) in repairs:
            out = np.array(p.out)  # copy: np.asarray of a jax array is read-only
            out[idx] = np.asarray(fixed)
            p.out = out

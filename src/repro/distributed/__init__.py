"""Distribution layer: logical-axis sharding resolver, fault tolerance."""

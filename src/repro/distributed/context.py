"""Activation-sharding context.

Model code is mesh-agnostic; the launcher activates a ShardingPolicy and the
model pins its activations through ``constrain(x, names)`` at a few strategic
points (embedding output, residual stream at layer boundaries, logits).

Why this is load-bearing: with FSDP-sharded weights, XLA's sharding
propagation is free to push a *weight* axis into the *activation* layout —
e.g. embed table (vocab->tensor, embed->fsdp) makes the embedding output
inherit fsdp on d_model, which replicates the batch axis on every device and
blows per-device activation memory by the dp degree (observed: 22.6 GB/dev
on a 3B model).  Pinning the residual stream to (batch->dp, seq->sp, embed->
None) makes XLA all-gather the weights at use instead — i.e. actual FSDP
semantics.
"""

from __future__ import annotations

import contextlib

import jax

_ACTIVE = None


@contextlib.contextmanager
def activate(policy):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = policy
    try:
        yield policy
    finally:
        _ACTIVE = prev


def active_policy():
    return _ACTIVE


def constrain(x, names: tuple):
    """Pin activation x to the active policy's layout for logical dim names
    ("batch", "seq", "embed", "vocab", "heads", ...).  Identity when no
    policy is active (tests, single-device examples)."""
    if _ACTIVE is None:
        return x
    from jax.sharding import NamedSharding

    spec = _ACTIVE.act_pspec(names, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_ACTIVE.mesh, spec))

"""Logical-axis -> mesh-axis resolution with divisibility fallbacks.

Params carry logical axis names (see models/layers.py); this module turns
them into PartitionSpecs for a concrete mesh:

  "vocab" / "heads" / "kv" / "mlp" / "expert"  -> the tensor axis ("model")
  "embed"                                      -> the FSDP axes ("pod","data")
  "lora" / "layers" / "conv" / "ssm" / ...     -> replicated

A dim is only sharded if its size divides the product of the target axes and
no axis is consumed twice within one spec — otherwise it silently falls back
to replication (e.g. kv=8 heads on a 16-way tensor axis).  The same policy
object also resolves activation batches and per-family KV-cache layouts
(where the fallback chain is what makes long_500k's batch=1 cells shardable:
batch unshardable -> the sequence dim absorbs the idle axes).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

TENSOR = "model"
FSDP = ("pod", "data")   # whichever are present in the mesh, in this order


def _present(mesh, axes):
    return tuple(a for a in axes if a in mesh.axis_names)


def _axes_size(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


@dataclass
class ShardingPolicy:
    mesh: object
    # logical name -> candidate mesh-axis groups, tried in order
    rules: dict = field(default_factory=dict)
    # replicate weights instead of FSDP-sharding them (hillclimb lever)
    fsdp: bool = True
    # decode-time contraction-dim parallelism: replicate the batch axis and
    # let the FSDP-sharded weight contraction psum activation partials
    # instead of all-gathering weights (hillclimb lever for decode cells —
    # activations are tiny per token, weights are not)
    batch_replicated: bool = False

    def __post_init__(self):
        if not self.rules:
            fsdp_axes = _present(self.mesh, FSDP) if self.fsdp else ()
            self.rules = {
                "vocab": [(TENSOR,)],
                "embed": [fsdp_axes] if fsdp_axes else [],
                "embed_out": [],
                "heads": [(TENSOR,)],
                "kv": [(TENSOR,)],
                "mlp": [(TENSOR,)],
                "expert": [(TENSOR,)],
                "lora": [],
                "layers": [],
                "conv": [],
                "ssm": [],
            }

    # ------------------------------------------------------------- params
    def param_pspec(self, axes: tuple, shape: tuple) -> P:
        used: set = set()
        parts = []
        for dim, name in zip(shape, axes):
            pick = None
            for cand in self.rules.get(name, []):
                group = tuple(a for a in _present(self.mesh, cand) if a not in used)
                if group and dim % _axes_size(self.mesh, group) == 0:
                    pick = group if len(group) > 1 else group[0]
                    used.update(group)
                    break
            parts.append(pick)
        return P(*parts)

    def param_shardings(self, specs_tree, shapes_tree):
        is_spec = lambda x: isinstance(x, tuple) and all(
            isinstance(t, (str, type(None))) for t in x
        )
        return jax.tree.map(
            lambda ax, shp: NamedSharding(self.mesh, self.param_pspec(ax, shp.shape)),
            specs_tree,
            shapes_tree,
            is_leaf=is_spec,
        )

    # -------------------------------------------------------- activations
    def dp_axes(self) -> tuple:
        return _present(self.mesh, FSDP)

    def batch_pspec(self, shape: tuple) -> P:
        """Leading dim = global batch over the dp axes (with divisibility)."""
        if self.batch_replicated:
            return P(*([None] * len(shape)))
        dp = self.dp_axes()
        if shape and shape[0] % _axes_size(self.mesh, dp) == 0:
            lead = dp if len(dp) > 1 else dp[0]
        else:
            lead = None
        return P(lead, *([None] * (len(shape) - 1)))

    def batch_shardings(self, batch_tree):
        return jax.tree.map(
            lambda leaf: NamedSharding(self.mesh, self.batch_pspec(leaf.shape)), batch_tree
        )

    # ------------------------------------------------------------- caches
    def _greedy(self, shape, priorities):
        """priorities: list of (dim_index, [axis groups to try]) — assign
        greedily without reusing axes; everything else replicated."""
        parts = [None] * len(shape)
        used: set = set()
        for dim_idx, groups in priorities:
            for cand in groups:
                group = tuple(a for a in _present(self.mesh, cand) if a not in used)
                if group and shape[dim_idx] % _axes_size(self.mesh, group) == 0:
                    parts[dim_idx] = group if len(group) > 1 else group[0]
                    used.update(group)
                    break
        return P(*parts)

    def cache_pspec(self, path_name: str, shape: tuple) -> P:
        dp = [self.dp_axes()]
        if path_name in ("k", "v") and len(shape) == 5:
            # (L, B, Hkv, S, hd): batch -> dp; heads -> tensor; seq soaks up
            # whatever is left (the long_500k batch=1 fallback).
            return self._greedy(
                shape, [(1, dp), (2, [(TENSOR,)]), (3, dp + [(TENSOR,)])]
            )
        if path_name in ("c", "kr") and len(shape) == 4:
            # (L, B, S, d): MLA latents — shard seq on tensor axis
            return self._greedy(shape, [(1, dp), (2, [(TENSOR,)] + dp)])
        if path_name == "ssm" and len(shape) == 5:
            return self._greedy(shape, [(1, dp), (2, [(TENSOR,)])])
        if path_name == "wkv" and len(shape) == 5:
            return self._greedy(shape, [(1, dp), (2, [(TENSOR,)])])
        if path_name == "conv" and len(shape) == 4:
            return self._greedy(shape, [(1, dp), (3, [(TENSOR,)])])
        if path_name in ("shift_tm", "shift_cm") and len(shape) == 4:
            return self._greedy(shape, [(1, dp), (3, [(TENSOR,)])])
        if len(shape) >= 2:
            return self._greedy(shape, [(1, dp)])
        return P(*([None] * len(shape)))

    def cache_shardings(self, caches_tree):
        def leaf(path, x):
            name = None
            for entry in reversed(path):
                if hasattr(entry, "key"):
                    name = entry.key
                    break
            return NamedSharding(self.mesh, self.cache_pspec(name, x.shape))

        return jax.tree_util.tree_map_with_path(leaf, caches_tree)

    # -------------------------------------------------- activation layout
    # logical activation dim -> candidate mesh axes (with divisibility)
    seq_shard: bool = False   # sequence parallelism for the residual stream

    def act_pspec(self, names: tuple, shape: tuple) -> P:
        used: set = set()
        parts = []
        for dim, name in zip(shape, names):
            groups: list = []
            if name == "batch":
                groups = [self.dp_axes()]
            elif name == "seq" and self.seq_shard:
                groups = [(TENSOR,)]
            elif name in ("vocab", "heads", "mlp", "expert"):
                groups = [(TENSOR,)]
            pick = None
            for cand in groups:
                group = tuple(a for a in _present(self.mesh, cand) if a not in used)
                if group and dim % _axes_size(self.mesh, group) == 0:
                    pick = group if len(group) > 1 else group[0]
                    used.update(group)
                    break
            parts.append(pick)
        return P(*parts)

    # ------------------------------------------------------------ scalars
    def replicated(self):
        return NamedSharding(self.mesh, P())

"""Deterministic synthetic LM data pipeline.

Design points that matter at pod scale (DESIGN.md Section 5):

  * determinism by construction — batch (step, host_shard) is a pure function
    of (seed, step, shard), so a restarted or re-sharded job regenerates
    exactly the stream it would have seen: checkpoint/restart and elastic
    re-sharding need no data-state checkpointing at all;
  * zero host copies on the hot path — token blocks are generated with a
    counter-based hash directly in jnp (device-resident), mimicking a
    tokenized+packed corpus reader;
  * a background prefetch thread with a bounded queue hides generation
    latency (the real-cluster analog: overlapping host->device transfer
    of the next batch with the current step).

The "language" is a Zipfian unigram stream with a Markov bigram overlay —
enough structure for loss to fall during the example runs.
"""

from __future__ import annotations

import queue
import threading

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.specs import _token_batch_shapes


class SyntheticLM:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0, prefetch: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- pure batch function ------------------------------------------------
    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        cfg, shape = self.cfg, self.shape
        shapes = _token_batch_shapes(cfg, shape, with_targets=True)
        (B, S_tok) = shapes["tokens"][0]
        # Zipf unigrams + shifted-repeat bigram structure
        base = rng.zipf(1.3, size=(B, S_tok + 1)) % cfg.vocab
        repeat = rng.random((B, S_tok + 1)) < 0.3
        seq = np.where(repeat, np.roll(base, 1, axis=1), base).astype(np.int32)
        out = {"tokens": jnp.asarray(seq[:, :-1]), "targets": jnp.asarray(seq[:, 1:])}
        for k, (s, d) in shapes.items():
            if k in ("tokens", "targets"):
                continue
            out[k] = jnp.asarray(rng.standard_normal(s) * 0.02, d)
        return out

    # -- prefetch loop ------------------------------------------------------
    def start(self, first_step: int = 0):
        def loop():
            step = first_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def next(self) -> dict:
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

"""Data substrate: synthetic LM pipeline + batch/spec builders shared by the
smoke tests, the training driver, and the multi-pod dry-run."""

from repro.data.specs import input_specs, make_batch
from repro.data.synthetic_lm import SyntheticLM

__all__ = ["input_specs", "make_batch", "SyntheticLM"]

"""Input specs (ShapeDtypeStruct stand-ins) and concrete batch builders for
every (arch x shape) cell.

The dry-run contract: ``input_specs(cfg, shape)`` returns exactly the pytree
the lowered step function consumes — weak-type-correct, shardable, zero
allocation.  Decode cells derive their cache specs by eval_shape-ing the
prefill path at the cell's seq_len, which guarantees the cache structure can
never drift from what the model actually produces.

Modality stubs: [vlm]/[audio] archs receive precomputed patch/frame
embeddings here (the assignment treats the frontend as a stub).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tfm


def _token_batch_shapes(cfg: ArchConfig, shape: ShapeConfig, *, with_targets: bool):
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    batch = {}
    if cfg.encoder_decoder:
        enc_len = min(cfg.enc_len, S)
        batch["frames"] = ((B, enc_len, cfg.d_model), dtype)
        batch["tokens"] = ((B, S), jnp.int32)
        if with_targets:
            batch["targets"] = ((B, S), jnp.int32)
    elif cfg.frontend:
        F = cfg.frontend_len
        batch["frontend"] = ((B, F, cfg.d_model), dtype)
        batch["tokens"] = ((B, S - F), jnp.int32)
        if with_targets:
            batch["targets"] = ((B, S - F), jnp.int32)
    else:
        batch["tokens"] = ((B, S), jnp.int32)
        if with_targets:
            batch["targets"] = ((B, S), jnp.int32)
    return batch


def _to_sds(shapes: dict) -> dict:
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: tfm.init_params(k, cfg)[0], jax.random.key(0))


def cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Cache pytree specs for a decode cell: eval_shape the prefill at this
    cell's seq_len (KV cache of seq_len, per the assignment)."""
    params = abstract_params(cfg)
    prefill_batch = _to_sds(_token_batch_shapes(cfg, shape, with_targets=False))
    _, caches = jax.eval_shape(
        lambda p, b: tfm.prefill(p, cfg, b), params, prefill_batch
    )
    return caches


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    if shape.kind == "train":
        return _to_sds(_token_batch_shapes(cfg, shape, with_targets=True))
    if shape.kind == "prefill":
        return _to_sds(_token_batch_shapes(cfg, shape, with_targets=False))
    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "caches": cache_specs(cfg, shape),
        }
    raise ValueError(shape.kind)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    shapes = _token_batch_shapes(cfg, shape, with_targets=(shape.kind == "train"))
    out = {}
    for k, (s, d) in shapes.items():
        if d == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=s), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s), d)
    return out

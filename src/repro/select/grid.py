"""Lambda grids: the ONE normalization chokepoint + the auto grid.

Every path surface — ``glasso_path``, ``Engine.run_path``, the streaming
``stream_screen``/``plan_path_streaming``, and the serving ``PathSpec`` —
funnels its grid through ``normalize_lambda_grid``: sort descending (the
homotopy/Theorem-2 direction), dedupe exactly, reject non-positive or
non-finite values.  Before this chokepoint each caller re-sorted privately
and silently accepted duplicates (two identical solves) and lam <= 0 (a
meaningless eq.-(4) threshold).

``lambda_grid`` builds the standard log-spaced grid anchored at

    lambda_max = max_{i != j} |S_ij|

— the smallest lambda at which the strict threshold (eq. 4) screens EVERY
vertex into a singleton, i.e. the top of any useful path.  From the dense S
that is one masked scan; from the raw data matrix
(``lambda_max_from_data``) it is computed EXACTLY without materializing S:
the per-tile Cauchy-Schwarz bounds ``norms_max[ti] * norms_max[tj]`` (the
same quantities the streaming screener's skip predicate uses) upper-bound
every tile pair's entries, so scanning pairs in descending bound order and
stopping once the bound falls below the running maximum touches only the
few tiles that can still matter (``select.grid.tiles_scanned`` vs
``select.grid.tiles_pruned``).

This module imports only numpy + the stream tiling primitives, so the
engine/planner/stream chokepoint call sites can import it lazily without
cycles.
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump

__all__ = [
    "normalize_lambda_grid",
    "lambda_max",
    "lambda_max_from_data",
    "lambda_grid",
]


def normalize_lambda_grid(lambdas) -> list[float]:
    """Canonicalize a lambda grid: strictly descending floats, deduped.

    Raises ``ValueError`` on an empty grid and on any non-finite or
    non-positive value — lam <= 0 makes the strict threshold |S_ij| > lam
    vacuous (and the penalized objective (1) unregularized), which every
    historical caller would have solved silently."""
    vals = [float(v) for v in np.asarray(list(lambdas), dtype=object).ravel()]
    if not vals:
        raise ValueError("empty lambda grid")
    for v in vals:
        if not np.isfinite(v) or v <= 0.0:
            raise ValueError(
                f"lambda grid values must be finite and positive, got {v!r}"
            )
    return sorted(set(vals), reverse=True)


def lambda_max(S) -> float:
    """max off-diagonal |S_ij| of a dense covariance — the grid anchor.

    Scans row-wise so no (p, p) temporary beyond the input is created."""
    S = np.asarray(S)
    p = S.shape[0]
    if p < 2:
        return 0.0
    best = 0.0
    for i in range(p):
        row = np.abs(S[i].astype(np.float64))  # copy: never mutate S
        row[i] = 0.0
        best = max(best, float(row.max()))
    return best


def lambda_max_from_data(X, *, config=None) -> float:
    """Exact lambda_max straight from the (n, p) data matrix — no dense S.

    One moments pass (``stream.tiler.column_moments``) yields the per-column
    sqrt(S_ii); tile pairs are then visited in DESCENDING Cauchy-Schwarz
    bound order and the scan stops as soon as the next bound cannot beat the
    running maximum.  Each visited pair computes its centered Gram block in
    row chunks (the screener's accumulation idiom), so peak memory stays
    O(n * tile + tile^2)."""
    from repro.stream.config import as_config
    from repro.stream.tiler import column_moments, tile_maxima

    X = np.asarray(X)
    n, p = X.shape
    cfg = as_config(config)
    moments = column_moments(X, chunk=cfg.chunk)
    norms_max = tile_maxima(moments.norms, cfg.tile)
    ti, tj = np.triu_indices(norms_max.shape[0])
    bound = norms_max[ti] * norms_max[tj]
    order = np.argsort(-bound, kind="stable")

    best = 0.0
    scanned = 0
    for k in order:
        if bound[k] <= best:
            break
        i, j = int(ti[k]), int(tj[k])
        ci = slice(i * cfg.tile, min((i + 1) * cfg.tile, p))
        cj = slice(j * cfg.tile, min((j + 1) * cfg.tile, p))
        blk = np.zeros((ci.stop - ci.start, cj.stop - cj.start))
        for r0 in range(0, n, cfg.chunk):
            rows = X[r0 : r0 + cfg.chunk].astype(np.float64, copy=False)
            blk += (rows[:, ci] - moments.mu[ci]).T @ (rows[:, cj] - moments.mu[cj])
        blk = np.abs(blk) / n
        if i == j:
            np.fill_diagonal(blk, 0.0)
        best = max(best, float(blk.max(initial=0.0)))
        scanned += 1
    bump("select.grid.tiles_scanned", scanned)
    bump("select.grid.tiles_pruned", int(ti.size - scanned))
    return best


def lambda_grid(
    S=None,
    *,
    X=None,
    n_points: int = 20,
    scale: str = "log",
    lam_min_ratio: float = 0.1,
    config=None,
) -> list[float]:
    """The auto grid: ``n_points`` values from lambda_max down to
    ``lam_min_ratio * lambda_max``, log-spaced by default.

    Pass the dense covariance ``S`` OR the raw data matrix ``X`` (anchored
    via ``lambda_max_from_data`` — S is never formed).  The top grid point
    sits exactly at lambda_max, where the strict threshold screens every
    vertex isolated — the all-singleton end of the path."""
    if (S is None) == (X is None):
        raise ValueError("lambda_grid needs exactly one of S or X=")
    if n_points < 1:
        raise ValueError(f"n_points must be >= 1, got {n_points}")
    if not 0.0 < lam_min_ratio <= 1.0:
        raise ValueError(f"lam_min_ratio must be in (0, 1], got {lam_min_ratio}")
    anchor = lambda_max(S) if S is not None else lambda_max_from_data(X, config=config)
    if anchor <= 0.0:
        raise ValueError(
            "lambda_max is 0 — no off-diagonal covariance signal to grid over"
        )
    if scale == "log":
        grid = np.geomspace(anchor, anchor * lam_min_ratio, n_points)
    elif scale == "linear":
        grid = np.linspace(anchor, anchor * lam_min_ratio, n_points)
    else:
        raise ValueError(f"scale must be 'log' or 'linear', got {scale!r}")
    return normalize_lambda_grid(grid)

"""Model selection as a service: the lambda path, scored and decided.

The source paper's experiments are PATH experiments — screening makes the
whole descending grid nearly free, components only merge as lambda drops
(Theorem 2), and the interesting question becomes "which lambda?".  This
package answers it end to end:

    grid        normalize_lambda_grid (THE grid chokepoint shared with
                glasso_path / run_path / stream_screen / PathSpec),
                lambda_max (+ the exact streamed variant), lambda_grid
    homotopy    warm-started path execution + select.warm.* accounting
    criteria    per-component Gaussian loglik + EBIC (CovSource blocks)
    stability   StARS over streamed subsample paths
    cv          k-fold held-out log-likelihood
    report      select_path -> Selection(result, report, path)

Serving admission: ``launch.control_plane.PathSpec`` carries (grid,
criterion, ...) through the same ``submit(spec, meta=)`` chokepoint as
every other request kind; the batcher resolves it by calling
``select_path`` — served and offline selections are bitwise identical.
"""

from repro.select.criteria import (
    CovSource,
    ebic_score,
    gaussian_loglik,
    loglik_terms,
)
from repro.select.cv import kfold_cv
from repro.select.grid import (
    lambda_grid,
    lambda_max,
    lambda_max_from_data,
    normalize_lambda_grid,
)
from repro.select.homotopy import homotopy_path, warm_counts
from repro.select.report import CRITERIA, Selection, SelectionReport, select_path
from repro.select.stability import stars

__all__ = [
    "CRITERIA",
    "CovSource",
    "Selection",
    "SelectionReport",
    "ebic_score",
    "gaussian_loglik",
    "homotopy_path",
    "kfold_cv",
    "lambda_grid",
    "lambda_max",
    "lambda_max_from_data",
    "loglik_terms",
    "normalize_lambda_grid",
    "select_path",
    "stars",
    "warm_counts",
]

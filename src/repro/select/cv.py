"""K-fold cross-validated Gaussian log-likelihood over the lambda path.

Each fold's training rows run the full homotopy path through the streamed
screener (``Engine.run_path_from_data`` — no dense S), and every path
result is scored on the HELD-OUT rows per component:

    score_fold(lam) = logdet Theta_lam - tr(S_test Theta_lam)

where the test-covariance blocks are gathered through ``CovSource`` for
exactly the vertices of each estimated component (plus the isolated
closed-form diagonal terms) — the held-out trace is a sum of per-block
products, never a global dense one.  Fold scores are weighted by held-out
size and averaged; the SELECTED lambda maximizes the mean held-out
log-likelihood (argmax — the opposite sign convention from EBIC's argmin,
normalized by ``select_path`` into a single report).
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump
from repro.engine.api import Engine
from repro.engine.options import EngineOptions
from repro.select.criteria import CovSource, loglik_terms
from repro.select.grid import normalize_lambda_grid

__all__ = ["kfold_cv"]


def kfold_cv(
    X,
    lambdas,
    *,
    options: EngineOptions | None = None,
    stream=None,
    k: int = 5,
    seed: int = 0,
) -> dict:
    """Run k-fold CV over a descending grid; returns per-lambda mean
    held-out log-likelihood ``scores`` (higher is better), the argmax
    ``selected_index``, and the fold parameters."""
    X = np.asarray(X)
    n = X.shape[0]
    lams = normalize_lambda_grid(lambdas)
    if not 2 <= k <= n:
        raise ValueError(f"k must be in [2, n={n}], got {k}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    engine = Engine(options=options if options is not None else EngineOptions())

    scores = np.zeros(len(lams))
    for fi, test_rows in enumerate(folds):
        train_rows = np.concatenate(
            [f for fj, f in enumerate(folds) if fj != fi]
        )
        results = engine.run_path_from_data(X[train_rows], lams, stream=stream)
        held_out = CovSource(X=X[test_rows])
        for li, res in enumerate(results):
            ld, tr = loglik_terms(res, held_out)
            scores[li] += (ld - tr) * len(test_rows)
        bump("select.cv.folds")
    scores /= n
    return {
        "scores": [float(v) for v in scores],
        "selected_index": int(np.argmax(scores)),
        "k": int(k),
        "seed": int(seed),
    }

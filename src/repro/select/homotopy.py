"""The homotopy path executor: largest lambda down, warm all the way.

Thin driver over ``Engine.run_path`` / ``run_path_from_data`` — the engine
already plans the whole descending grid from one union-find pass (Theorem
2) and warm-starts every bucket: unchanged buckets resume from their own
previous padded solutions on device, and merged components start from the
block-diagonal stack of their children's Thetas (``blockwise_inverse`` /
``SparseTheta.gather_block``, whose cross-component entries are exact
zeros by Theorem 1 — a valid PD iterate).  What this module adds is the
ACCOUNTING: ``Engine._execute_path`` bumps, per solver-bound bucket,

    select.warm.reused   warm-started from its own previous solution
    select.warm.merged   warm-started from the merged blockwise inverse
    select.warm.cold     no warm source (first grid point, warm_start=False,
                         a non-warm-capable solver, or a fresh sharded block)

and ``warm_counts()`` reads them back — the homotopy acceptance metric
(bench_select gates on the warm fraction) and the ``SelectionReport.warm``
field both come from these counters.  Buckets on closed-form/chordal routes
are solved directly either way and are never counted.
"""

from __future__ import annotations

from repro.core.instrument import tail_counts
from repro.engine.api import Engine, GlassoResult
from repro.engine.options import EngineOptions
from repro.select.grid import normalize_lambda_grid

__all__ = ["homotopy_path", "warm_counts"]


def homotopy_path(
    S=None,
    *,
    X=None,
    lambdas,
    options: EngineOptions | None = None,
    warm_start: bool = True,
    stream=None,
    p_max: int | None = None,
    output: str | None = None,
) -> list[GlassoResult]:
    """Solve a descending lambda grid with full warm-start reuse.

    Pass the dense covariance ``S`` or the raw data matrix ``X`` (screened
    out-of-core — the dense S never exists).  ``warm_start=False`` is the
    cold-restart baseline arm (identical planning, every solver-bound
    bucket starts from scratch) that bench_select measures against.
    Results are exactly ``glasso_path``'s — the selection layer is built on
    the public path contract, not beside it."""
    if (S is None) == (X is None):
        raise ValueError("homotopy_path needs exactly one of S or X=")
    lams = normalize_lambda_grid(lambdas)
    engine = Engine(options=options if options is not None else EngineOptions())
    if X is not None:
        return engine.run_path_from_data(
            X, lams, stream=stream, warm_start=warm_start, p_max=p_max,
            output=output,
        )
    return engine.run_path(
        S, lams, warm_start=warm_start, p_max=p_max, output=output
    )


def warm_counts() -> dict[str, int]:
    """The ``select.warm.*`` counters since the last ``instrument.reset``:
    {"reused": ..., "merged": ..., "cold": ...} (absent keys = 0 bumps)."""
    return tail_counts("select.warm.")

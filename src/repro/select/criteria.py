"""Per-component selection criteria: Gaussian log-likelihood and EBIC.

Theorem 1 makes every path result block-diagonal over its screened
components, so the Gaussian log-likelihood decomposes exactly:

    logdet(Theta) = sum_c logdet(Theta_c)  +  sum_{iso} log(theta_ii)
    tr(S Theta)   = sum_c tr(S_c Theta_c)  +  sum_{iso} S_ii * theta_ii

Both sides are computed HERE per component — per-block slogdet plus a trace
against the gathered S block — never as a global dense product, so scoring
a sparse result costs O(sum b_i^2) like everything else on the sparse path.

``CovSource`` supplies the S blocks from either modality: a dense
covariance gathers directly; the raw (n, p) data matrix centers the needed
columns on demand (an (n, b) temporary per block — the dense (p, p) S is
never formed, matching the streaming screener's contract).

EBIC (Foygel & Drton, 2010), on the ``-2 loglik`` scale (argmin selects):

    EBIC_gamma(lam) = -n (logdet Theta - tr(S Theta))
                      + |E| log n + 4 gamma |E| log p

with |E| the off-diagonal support size; gamma = 0 recovers plain BIC and
gamma = 0.5 is the standard high-dimensional default.
"""

from __future__ import annotations

import numpy as np

from repro.core.blocks import gather_diag, gather_submatrix
from repro.core.components import component_lists
from repro.core.sparse import SparseTheta

__all__ = ["CovSource", "loglik_terms", "gaussian_loglik", "ebic_score"]


class CovSource:
    """Per-component covariance blocks from a dense S or the raw X.

    One object, two modalities, one ``block``/``diag`` surface — the
    criteria below never learn which input produced the blocks.  From X the
    blocks are the centered Gram restriction ((X - mu)' (X - mu) / n over
    the requested columns), identical to the dense S entries up to f64
    accumulation order."""

    def __init__(self, S=None, X=None):
        if (S is None) == (X is None):
            raise ValueError("CovSource needs exactly one of S or X")
        self._S = S if S is None or hasattr(S, "gather_block") else np.asarray(S)
        self._X = None
        self.n = None
        if X is not None:
            X = np.asarray(X)
            self._X = X
            self.n = int(X.shape[0])
            self._mu = X.astype(np.float64, copy=False).mean(axis=0)

    @property
    def p(self) -> int:
        return int(self._S.shape[0] if self._S is not None else self._X.shape[1])

    def block(self, idx: np.ndarray) -> np.ndarray:
        """S[np.ix_(idx, idx)] for one component's vertex set."""
        if self._S is not None:
            return np.asarray(gather_submatrix(self._S, np.asarray(idx)))
        C = self._X[:, idx].astype(np.float64, copy=False) - self._mu[idx]
        return C.T @ C / self.n

    def diag(self, idx) -> np.ndarray:
        """S[idx, idx] for isolated vertices."""
        if self._S is not None:
            return np.asarray(gather_diag(self._S, np.asarray(idx)))
        C = self._X[:, idx].astype(np.float64, copy=False) - self._mu[idx]
        return (C * C).sum(axis=0) / self.n


def _logdet_pd(blk: np.ndarray) -> float:
    sign, val = np.linalg.slogdet(blk)
    return float(val) if sign > 0 else -np.inf


def loglik_terms(result, src: CovSource) -> tuple[float, float]:
    """(logdet Theta, tr(S Theta)) of one path result, summed per component.

    ``result`` is a ``GlassoResult`` whose Theta is dense or a
    ``SparseTheta``; ``src`` supplies the matching S blocks.  Isolated
    vertices contribute their closed-form log(theta_ii) / S_ii * theta_ii
    terms — they carry lambda dependence too."""
    Theta = result.Theta
    ld = 0.0
    tr = 0.0
    if isinstance(Theta, SparseTheta):
        for c, blk in Theta.blocks():
            ld += _logdet_pd(np.asarray(blk))
            tr += float(np.sum(src.block(c) * blk))
        if Theta.isolated.size:
            vals = np.asarray(Theta.isolated_values, dtype=np.float64)
            ld += float(np.sum(np.log(vals)))
            tr += float(np.sum(src.diag(Theta.isolated) * vals))
        return ld, tr
    Theta = np.asarray(Theta)
    for comp in component_lists(np.asarray(result.labels)):
        blk = Theta[np.ix_(comp, comp)]
        if comp.size == 1:
            v = float(blk[0, 0])
            ld += np.log(v) if v > 0 else -np.inf
            tr += float(src.diag(comp)[0]) * v
        else:
            ld += _logdet_pd(blk)
            tr += float(np.sum(src.block(comp) * blk))
    return ld, tr


def gaussian_loglik(result, src: CovSource, n: int) -> float:
    """Gaussian log-likelihood (n/2)(logdet Theta - tr(S Theta)), dropping
    the data-independent constant — the quantity CV evaluates on held-out
    covariance blocks."""
    ld, tr = loglik_terms(result, src)
    return 0.5 * float(n) * (ld - tr)


def ebic_score(result, src: CovSource, n: int, *, gamma: float = 0.5) -> float:
    """Extended BIC of one path result (lower is better; argmin selects)."""
    if n is None or n <= 0:
        raise ValueError("EBIC needs the sample count n > 0")
    if gamma < 0:
        raise ValueError(f"EBIC gamma must be >= 0, got {gamma}")
    ld, tr = loglik_terms(result, src)
    n_edges = int(result.support_edges().shape[0])
    p = int(result.Theta.shape[0])
    return float(
        -n * (ld - tr) + n_edges * (np.log(n) + 4.0 * gamma * np.log(p))
    )

"""StARS: Stability Approach to Regularization Selection (Liu et al., 2010).

For each of ``n_subsamples`` row subsamples of size b (default the paper's
b = floor(10 sqrt(n)), capped at n - 1), the whole lambda path runs through
the STREAMED screener (``Engine.run_path_from_data``) — one tiled pass over
the subsample per path, materialized per-component blocks, and never a
dense (p, p) S.  Per lambda, each edge's selection frequency xi_ij is the
fraction of subsamples whose estimated graph contains it, its instability
is 2 xi (1 - xi), and the total instability

    D(lam) = sum_{i<j} 2 xi_ij (1 - xi_ij) / (p choose 2)

is accumulated SPARSELY over the edges actually observed (an edge absent
from every subsample has xi = 0 and contributes nothing).  Because
components only merge as lambda drops (Theorem 2), instability is
monotonized along the descending grid (Dbar = running max) and StARS
selects the SMALLEST lambda with Dbar <= beta — the sparsest graph whose
support is reproducible under resampling.  Falls back to the largest
(most regularized) lambda when no grid point meets beta.
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump
from repro.engine.api import Engine
from repro.engine.options import EngineOptions
from repro.select.grid import normalize_lambda_grid

__all__ = ["stars"]


def stars(
    X,
    lambdas,
    *,
    options: EngineOptions | None = None,
    stream=None,
    n_subsamples: int = 20,
    subsample_size: int | None = None,
    beta: float = 0.05,
    seed: int = 0,
) -> dict:
    """Run StARS over a descending grid; returns a dict with per-lambda
    ``scores`` (instability D), the monotonized ``monotone`` curve, the
    ``selected_index`` into the normalized descending grid, and the
    resampling parameters used."""
    X = np.asarray(X)
    n, p = X.shape
    lams = normalize_lambda_grid(lambdas)
    if n_subsamples < 2:
        raise ValueError(f"StARS needs >= 2 subsamples, got {n_subsamples}")
    if not 0.0 < beta < 1.0:
        raise ValueError(f"beta must be in (0, 1), got {beta}")
    b = subsample_size if subsample_size is not None else int(10.0 * np.sqrt(n))
    b = int(min(max(b, 2), n - 1)) if n > 2 else n
    rng = np.random.default_rng(seed)
    engine = Engine(options=options if options is not None else EngineOptions())

    # per-lambda lists of observed-edge keys (i * p + j), one array per
    # subsample — frequencies come from one np.unique at the end
    observed: list[list[np.ndarray]] = [[] for _ in lams]
    for _ in range(n_subsamples):
        rows = rng.choice(n, size=b, replace=False)
        results = engine.run_path_from_data(X[rows], lams, stream=stream)
        for li, res in enumerate(results):
            e = res.support_edges()
            if len(e):
                observed[li].append(e[:, 0].astype(np.int64) * p + e[:, 1])
        bump("select.stars.subsamples")

    denom = p * (p - 1) / 2.0
    scores = []
    for li in range(len(lams)):
        if observed[li]:
            _, counts = np.unique(np.concatenate(observed[li]), return_counts=True)
            xi = counts / float(n_subsamples)
            scores.append(float(np.sum(2.0 * xi * (1.0 - xi)) / denom))
        else:
            scores.append(0.0)
    monotone = np.maximum.accumulate(scores)  # descending grid: instability grows
    ok = np.flatnonzero(monotone <= beta)
    selected = int(ok[-1]) if ok.size else 0
    return {
        "scores": scores,
        "monotone": [float(v) for v in monotone],
        "selected_index": selected,
        "beta": float(beta),
        "n_subsamples": int(n_subsamples),
        "subsample_size": int(b),
    }

"""``select_path``: one warm homotopy path, one criterion, one report.

The front door of ``repro.select``: resolve the grid (explicit list,
``{"auto": n}``, or a bare int — all anchored at lambda_max when auto),
run the warm-started homotopy path ONCE on the full data, score every grid
point with the requested criterion, and return a ``Selection``:

    selection.result    the criterion-selected GlassoResult
    selection.path      every per-lambda result, largest lambda first
    selection.report    SelectionReport — per-lambda score / support size /
                        component count / route mix / stage timings, the
                        selected index, and the warm-start accounting
                        (select.warm.* counter deltas for THIS path)

Criteria semantics: "ebic" minimizes (needs the sample count — implicit
from X, ``n=`` with a covariance input); "cv" and "stars" resample rows and
therefore REQUIRE the data matrix ``X`` (their extra paths run through the
streamed screener per subsample/fold; the reported path is still the
full-data one, so the selected graph is always estimated from all rows).

The serving control plane's ``PathSpec`` admission calls THIS function on
the batcher thread — ``submit(PathSpec(...))`` is bitwise-identical to the
offline ``select_path(...)`` on the same inputs and options.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.instrument import tail_counts
from repro.engine.api import GlassoResult
from repro.engine.options import EngineOptions
from repro.obs.trace import span, trace_request
from repro.select.criteria import CovSource, ebic_score
from repro.select.grid import lambda_grid, normalize_lambda_grid
from repro.select.homotopy import homotopy_path

__all__ = ["CRITERIA", "SelectionReport", "Selection", "select_path"]

#: Criteria ``select_path`` (and the serving ``PathSpec``) accept.
CRITERIA = ("ebic", "cv", "stars")


@dataclass
class SelectionReport:
    """Per-lambda diagnostics + the selection decision for one path."""

    criterion: str
    lambdas: list[float]                 # descending, normalized
    scores: list[float]                  # criterion value per lambda
    selected_index: int
    support_sizes: list[int] = field(default_factory=list)
    n_components: list[int] = field(default_factory=list)
    route_mixes: list[dict] = field(default_factory=list)
    stages_us: list[dict] = field(default_factory=list)
    warm: dict = field(default_factory=dict)   # select.warm.* deltas
    detail: dict = field(default_factory=dict)  # criterion parameters

    @property
    def selected_lam(self) -> float:
        return self.lambdas[self.selected_index]

    @property
    def warm_fraction(self) -> float:
        """Warm-started share of this path's solver-bound buckets (reused +
        merged over all counted solves); 0.0 when nothing needed a solver."""
        total = sum(self.warm.values())
        if not total:
            return 0.0
        return (self.warm.get("reused", 0) + self.warm.get("merged", 0)) / total


@dataclass
class Selection:
    """What ``select_path`` returns (and what ``submit(PathSpec)`` resolves
    to): the selected result, the full path, and the report."""

    result: GlassoResult
    report: SelectionReport
    path: list[GlassoResult]


def _resolve_grid(grid, S, X, stream) -> list[float]:
    if grid is None:
        grid = {"auto": 20}
    if isinstance(grid, (int, np.integer)):
        grid = {"auto": int(grid)}
    if isinstance(grid, dict):
        if set(grid) != {"auto"}:
            raise ValueError(
                f"grid dict must be exactly {{'auto': n_points}}, got {grid!r}"
            )
        return lambda_grid(S, X=X, n_points=int(grid["auto"]), config=stream)
    return normalize_lambda_grid(grid)


def select_path(
    S=None,
    *,
    X=None,
    grid=None,
    criterion: str = "ebic",
    n: int | None = None,
    gamma: float = 0.5,
    options: EngineOptions | None = None,
    stream=None,
    output: str | None = None,
    criterion_opts=None,
) -> Selection:
    """Pick the best lambda on a descending grid; see the module docstring.

    ``grid`` is an explicit sequence, ``{"auto": n_points}``, a bare int
    (same as auto), or None (auto, 20 points); ``criterion_opts`` forwards
    criterion-specific knobs (cv: ``k``/``seed``; stars: ``n_subsamples``/
    ``subsample_size``/``beta``/``seed``; ebic: ``gamma`` overriding the
    kwarg)."""
    if (S is None) == (X is None):
        raise ValueError("select_path needs exactly one of S or X=")
    if criterion not in CRITERIA:
        raise ValueError(
            f"criterion must be one of {CRITERIA}, got {criterion!r}"
        )
    from contextlib import nullcontext

    copts = dict(criterion_opts or {})
    trace_ctx = (
        trace_request("select.path", criterion=criterion)
        if (options is None or options.trace)
        else nullcontext()
    )
    with trace_ctx:
        with span("select.grid"):
            lams = _resolve_grid(grid, S, X, stream)

        warm_before = tail_counts("select.warm.")
        results = homotopy_path(
            S, X=X, lambdas=lams, options=options, stream=stream,
            output=output,
        )
        warm = {
            k: v - warm_before.get(k, 0)
            for k, v in tail_counts("select.warm.").items()
            if v - warm_before.get(k, 0)
        }

        detail: dict = {}
        with span("select.score", criterion=criterion):
            if criterion == "ebic":
                n_obs = int(np.asarray(X).shape[0]) if X is not None else n
                if n_obs is None:
                    raise ValueError(
                        "EBIC needs the sample count: pass n= with a "
                        "covariance input"
                    )
                g = float(copts.pop("gamma", gamma))
                if copts:
                    raise TypeError(
                        f"unknown EBIC criterion_opts: {sorted(copts)}"
                    )
                src = CovSource(S=S) if S is not None else CovSource(X=X)
                scores = [ebic_score(r, src, n_obs, gamma=g) for r in results]
                selected = int(np.argmin(scores))
                detail = {"gamma": g, "n": int(n_obs)}
            elif criterion == "cv":
                if X is None:
                    raise ValueError(
                        "criterion 'cv' resamples rows and needs X="
                    )
                from repro.select.cv import kfold_cv

                out = kfold_cv(X, lams, options=options, stream=stream, **copts)
                scores, selected = out["scores"], out["selected_index"]
                detail = {
                    k: v for k, v in out.items()
                    if k not in ("scores", "selected_index")
                }
            else:  # stars
                if X is None:
                    raise ValueError(
                        "criterion 'stars' resamples rows and needs X="
                    )
                from repro.select.stability import stars

                out = stars(X, lams, options=options, stream=stream, **copts)
                scores, selected = out["scores"], out["selected_index"]
                detail = {
                    k: v for k, v in out.items()
                    if k not in ("scores", "selected_index")
                }

    report = SelectionReport(
        criterion=criterion,
        lambdas=[r.lam for r in results],
        scores=[float(v) for v in scores],
        selected_index=selected,
        support_sizes=[int(r.support_edges().shape[0]) for r in results],
        n_components=[
            int(r.screen.n_components) if r.screen is not None else 0
            for r in results
        ],
        route_mixes=[dict(r.route_mix) for r in results],
        stages_us=[r.stages_us for r in results],
        warm=warm,
        detail=detail,
    )
    return Selection(result=results[selected], report=report, path=results)

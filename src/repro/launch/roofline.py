"""Roofline analysis over the dry-run records.

Terms (per device == per chip; the dry-run records are post-SPMD):

    compute_s    = flops_per_device / PEAK_FLOPS      (197 TFLOP/s bf16)
    memory_s     = bytes_per_device / HBM_BW          (819 GB/s)
    collective_s = collective_bytes_per_device / ICI  (50 GB/s/link)

Dominant term = bottleneck.  MODEL_FLOPS = 6*N*D (train) or 2*N_active*D
(serve) per device; MODEL_FLOPS/HLO_FLOPS measures how much compiled compute
is "useful" (remat recompute, dispatch overhead, masked attention waste all
push it down).

Usage:
    python -m repro.launch.roofline               # markdown table
    python -m repro.launch.roofline --csv out.csv
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load_records(tag: str = "") -> list[dict]:
    recs = []
    for f in sorted(OUT_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def roofline_row(r: dict) -> dict:
    if r["status"] != "ok":
        return {**r, "dominant": "-"}
    chips = r["chips"]
    compute_s = r["flops_per_device"] / PEAK_FLOPS
    memory_s = r["bytes_per_device"] / HBM_BW
    collective_s = r["collective"].get("total", 0.0) / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    n = r["params_active"]  # MODEL_FLOPS uses 6*N_active*D for MoE (== total for dense)
    mult = 2 if r["kind"] == "decode" else (6 if r["kind"] == "train" else 2)
    model_flops_dev = mult * n * r["tokens_global"] / chips
    useful = model_flops_dev / max(r["flops_per_device"], 1.0)
    bound_s = max(terms.values())
    # roofline fraction: useful model flops per device-second at the peak,
    # achieved vs ideal (ideal = everything at the compute roof)
    ideal_s = model_flops_dev / PEAK_FLOPS
    frac = ideal_s / bound_s if bound_s > 0 else 0.0
    return {
        **r,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_dev": model_flops_dev,
        "useful_ratio": useful,
        "roofline_frac": frac,
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | status | compute_s | memory_s | collective_s "
           "| dominant | MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']}: "
                f"{r.get('reason','')[:48]} | - | - | - | - | - | - |\n"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |\n"
        )
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    args = ap.parse_args()
    rows = [roofline_row(r) for r in load_records(args.tag)]
    if args.mesh != "both":
        rows = [r for r in rows if r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows))
    if args.csv:
        import csv

        keys = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
                "collective_s", "dominant", "useful_ratio", "roofline_frac",
                "flops_per_device", "bytes_per_device"]
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
            w.writeheader()
            for r in rows:
                w.writerow(r)
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()

"""Production meshes.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (jax locks the device count on first init, and the
dry-run fakes 512 host devices before any jax import)."""

from __future__ import annotations



def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — DP across pods
    (the slow inter-pod links carry only gradient reductions), TP inside."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.core.jax_compat import make_mesh

    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for tests/examples on the container CPU."""
    from repro.core.jax_compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))

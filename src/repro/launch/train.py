"""Training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/run1

Fault-tolerance behaviour (exercised by tests/test_fault_tolerance.py):
  * --resume restarts from the newest complete checkpoint (atomic LATEST
    pointer) and — because the data pipeline is a pure function of
    (seed, step) — reproduces the exact trajectory bit-for-bit;
  * SIGTERM/SIGINT triggers a final synchronous checkpoint before exit
    (preemption handling);
  * per-step wall times are logged with an EWMA outlier flag — the
    single-host stand-in for pod-level straggler detection (on a real pod
    the same hook feeds the coordinator, DESIGN.md Section 5).

On real TPU this driver runs unchanged under jit+mesh; here it runs the
reduced configs on CPU (examples/train_lm.py drives a ~100M-param model).
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import numpy as np


def run_training(
    *,
    arch: str,
    steps: int,
    batch: int,
    seq: int,
    reduced: bool = True,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = False,
    microbatches: int = 1,
    optimizer_name: str = "adamw",
    lr: float = 3e-4,
    seed: int = 0,
    grad_compress: bool = False,
    log=print,
):
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ShapeConfig, get_arch
    from repro.data.synthetic_lm import SyntheticLM
    from repro.models.zoo import build_model, count_params
    from repro.optim import OPTIMIZERS, cosine_with_warmup
    from repro.train.state import init_state
    from repro.train.step import make_train_step

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")  # CPU-friendly
    shape = ShapeConfig("train_cli", seq_len=seq, global_batch=batch, kind="train")

    model = build_model(cfg)
    optimizer = OPTIMIZERS[optimizer_name](
        cosine_with_warmup(lr, warmup=max(10, steps // 20), total=steps)
    )
    state, _specs = init_state(model, optimizer, jax.random.key(seed))
    log(f"arch={cfg.name} reduced={reduced} params={count_params(state.params):,}")

    mgr = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
    start_step = 0
    if resume and mgr is not None:
        try:
            state, start_step = mgr.restore_latest(state)
            log(f"resumed from step {start_step}")
        except FileNotFoundError:
            log("no checkpoint found; cold start")

    step_fn = jax.jit(
        make_train_step(model, optimizer, microbatches=microbatches, remat="none")
    )
    data = SyntheticLM(cfg, shape, seed=seed)

    # preemption: save on SIGTERM/SIGINT, then exit cleanly
    interrupted = {"flag": False}

    def _on_signal(signum, frame):
        interrupted["flag"] = True

    old_term = signal.signal(signal.SIGTERM, _on_signal)
    old_int = signal.signal(signal.SIGINT, _on_signal)

    ewma, losses = None, []
    try:
        for step in range(start_step, steps):
            batch_data = data.batch_at(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_data)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            straggler = dt > 3.0 * ewma and step > start_step + 3
            losses.append(loss)
            if step % 10 == 0 or straggler:
                log(
                    f"step {step:5d} loss {loss:.4f} {dt*1e3:7.1f} ms"
                    + ("  [STRAGGLER]" if straggler else "")
                )
            if mgr is not None and mgr.should_save(step):
                mgr.save(int(state.step), state)
            if interrupted["flag"]:
                log(f"preemption signal at step {step}; checkpointing")
                if mgr is not None:
                    mgr.save(int(state.step), state, blocking=True)
                return state, losses, "preempted"
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
        if mgr is not None:
            mgr.wait()

    if mgr is not None:
        mgr.save(int(state.step), state, blocking=True)
    return state, losses, "done"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    state, losses, status = run_training(
        arch=args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=not args.full, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, microbatches=args.microbatches,
        optimizer_name=args.optimizer, lr=args.lr, seed=args.seed,
    )
    print(f"status={status} final_step={int(state.step)} "
          f"loss[first5]={np.round(losses[:5], 3).tolist()} "
          f"loss[last5]={np.round(losses[-5:], 3).tolist()}")


if __name__ == "__main__":
    main()

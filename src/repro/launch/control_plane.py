"""Serving control plane: typed request specs, tenant admission, bounded
queues, and result caching for ``launch.serve_glasso.GlassoServer``.

The server's three historical verbs (``submit``/``submit_data``/
``submit_joint``) each grew their own kwarg surface; this module is the
redesign's vocabulary.  WHAT to solve travels as one typed spec —

    DenseSpec(S, lam)                  covariance admission
    DataSpec(X, lam, session=...)      out-of-core data-matrix admission
    JointSpec(Ss=[...], lam1, lam2)    K-class joint admission (or Xs=)
    PathSpec(S|X, grid, criterion)     model selection over a lambda path
                                       (grid = sequence | {"auto": n};
                                       criterion = "ebic" | "cv" | "stars")

— and HOW to treat the request travels as ``RequestMeta``:

    tenant     accounting identity for per-tenant token-bucket quotas
    slo        "interactive" (admission fast path + priority dequeue) or
               "batch" (best-effort; yields the batching window to
               interactive co-travellers)
    deadline   relative seconds; an expired request is dropped BEFORE
               dispatch with ``DeadlineExceeded`` (never solved dead)
    output     per-request result representation override

Overload is EXPLICIT: a full bounded queue or an exhausted tenant bucket
raises ``Overload`` synchronously from ``submit`` (typed, with ``reason``)
instead of parking a future that will time out — backpressure the client
can act on.  ``ResultCache`` closes the loop above the process-global
compiled-solver cache: identical (spec bytes, lambdas, penalty, K, output)
re-submissions return the finished result without touching the planner.

Everything here is engine-agnostic plumbing (no jax imports): the server
composes it; tests exercise it in isolation.
"""

from __future__ import annotations

import hashlib
import queue
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "SLO_CLASSES",
    "AdmissionQueue",
    "DataSpec",
    "DeadlineExceeded",
    "DenseSpec",
    "JointSpec",
    "Overload",
    "PATH_CRITERIA",
    "PathSpec",
    "Quota",
    "RequestMeta",
    "ResultCache",
    "SolveSpec",
    "TenantBuckets",
    "TokenBucket",
    "deadline_instant",
    "fingerprint_array",
    "spec_cache_key",
]

SLO_CLASSES = ("interactive", "batch")


# ---------------------------------------------------------------------------
# typed errors — backpressure the client can branch on
# ---------------------------------------------------------------------------


class Overload(RuntimeError):
    """The control plane rejected a request at admission.

    ``reason`` is machine-readable: "queue" (bounded queue full) or
    "quota" (tenant token bucket exhausted).  Raised synchronously from
    ``submit`` — an overloaded server never hands back a future that will
    hang out a timeout."""

    def __init__(self, message: str, *, reason: str, tenant: str | None = None):
        super().__init__(message)
        self.reason = reason
        self.tenant = tenant


class DeadlineExceeded(TimeoutError):
    """The request's deadline expired before dispatch; delivered through the
    request future (the drop happens queue-side, never mid-solve)."""


# ---------------------------------------------------------------------------
# request specs: WHAT to solve
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DenseSpec:
    """A single-class request from the dense (p, p) covariance."""

    S: object
    lam: float

    @property
    def p(self) -> int:
        return int(np.asarray(self.S).shape[0])


@dataclass(frozen=True)
class DataSpec:
    """A single-class request from the raw (n, p) data matrix: screening
    runs out-of-core (``repro.stream``) — the dense S never exists.

    ``session`` names a pinned screen state for later incremental
    ``append_rows``; ``stream`` is a ``repro.stream.StreamConfig`` (or a
    kwargs dict) for this request."""

    X: object
    lam: float
    session: str | None = None
    stream: object = None

    @property
    def p(self) -> int:
        return int(np.asarray(self.X).shape[1])


@dataclass(frozen=True)
class JointSpec:
    """A K-class joint request (``repro.joint``): pass ``Ss`` (class
    covariances) or ``Xs`` (per-class data matrices, screened out-of-core),
    never both."""

    Ss: object = None
    lam1: float = 0.0
    lam2: float = 0.0
    penalty: str = "group"
    Xs: object = None
    stream: object = None

    def __post_init__(self):
        if (self.Ss is None) == (self.Xs is None):
            raise ValueError("JointSpec needs exactly one of Ss or Xs")

    @property
    def K(self) -> int:
        mats = self.Ss if self.Ss is not None else self.Xs
        return len(mats)

    @property
    def p(self) -> int:
        if self.Ss is not None:
            return int(np.asarray(self.Ss[0]).shape[0])
        return int(np.asarray(self.Xs[0]).shape[1])


#: Criteria a ``PathSpec`` may name — mirrors ``repro.select.CRITERIA``
#: (kept literal here so the control plane stays engine-import-free).
PATH_CRITERIA = ("ebic", "cv", "stars")


@dataclass(frozen=True)
class PathSpec:
    """A model-selection request: solve a descending lambda path (warm
    homotopy, ``repro.select``) and return the criterion-selected graph
    plus per-lambda diagnostics (a ``select.Selection``).

    ``grid`` is an explicit sequence of lambdas, ``{"auto": n_points}`` or
    a bare int (auto grid anchored at lambda_max), or None (auto, 20
    points).  ``criterion`` is one of ``PATH_CRITERIA``; "cv" and "stars"
    resample rows and therefore require the data-matrix form (``X=``).
    ``n`` is the sample count EBIC needs when only the covariance ``S`` is
    given; ``criterion_opts`` forwards criterion knobs (cv ``k``, stars
    ``n_subsamples``/``beta``, ...).  Path requests default to the "batch"
    SLO at admission — a whole grid of solves should not jump interactive
    co-travellers — and never take the admission fast path."""

    S: object = None
    X: object = None
    grid: object = None
    criterion: str = "ebic"
    n: int | None = None
    gamma: float = 0.5
    criterion_opts: object = None
    stream: object = None

    def __post_init__(self):
        if (self.S is None) == (self.X is None):
            raise ValueError("PathSpec needs exactly one of S or X")
        if self.criterion not in PATH_CRITERIA:
            raise ValueError(
                f"criterion must be one of {PATH_CRITERIA}, "
                f"got {self.criterion!r}"
            )
        if self.criterion in ("cv", "stars") and self.X is None:
            raise ValueError(
                f"criterion {self.criterion!r} resamples rows and needs "
                "the data-matrix form (X=)"
            )

    @property
    def p(self) -> int:
        if self.S is not None:
            return int(np.asarray(self.S).shape[0])
        return int(np.asarray(self.X).shape[1])


SolveSpec = DenseSpec | DataSpec | JointSpec | PathSpec


# ---------------------------------------------------------------------------
# request meta: HOW to treat it
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestMeta:
    """Per-request serving policy; orthogonal to the spec.

    ``deadline`` is RELATIVE seconds from admission (converted to an
    absolute monotonic instant inside the server); ``output`` overrides the
    server-level representation ("dense" | "sparse" | "auto")."""

    tenant: str = "default"
    slo: str = "interactive"
    deadline: float | None = None
    output: str | None = None

    def __post_init__(self):
        if self.slo not in SLO_CLASSES:
            raise ValueError(
                f"slo must be one of {SLO_CLASSES}, got {self.slo!r}"
            )
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError("deadline must be positive seconds")


# ---------------------------------------------------------------------------
# per-tenant token buckets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Quota:
    """Tenant admission budget: ``rate`` requests/second refill, ``burst``
    bucket capacity (momentary spike allowance)."""

    rate: float
    burst: float

    def __post_init__(self):
        if self.rate <= 0 or self.burst <= 0:
            raise ValueError("quota rate and burst must be positive")


class TokenBucket:
    """Classic token bucket; thread-safe; clock injectable for tests."""

    def __init__(self, quota: Quota, *, clock=time.monotonic):
        self.quota = quota
        self._clock = clock
        self._tokens = float(quota.burst)
        self._stamp = clock()
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> bool:
        with self._lock:
            now = self._clock()
            self._tokens = min(
                self.quota.burst,
                self._tokens + (now - self._stamp) * self.quota.rate,
            )
            self._stamp = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False

    @property
    def tokens(self) -> float:
        with self._lock:
            now = self._clock()
            return min(
                self.quota.burst,
                self._tokens + (now - self._stamp) * self.quota.rate,
            )


# ---------------------------------------------------------------------------
# bounded two-class priority queue
# ---------------------------------------------------------------------------


class AdmissionQueue:
    """Bounded dispatch queue with two strict priority levels.

    "interactive" items dequeue before any "batch" item (FIFO within a
    level) — the priority half of the SLO contract; the bounded half is
    ``try_put`` returning False when ``maxsize`` items are already waiting,
    which the server surfaces as a typed ``Overload``.  API mirrors the
    ``queue.Queue`` subset the batcher uses (``get(timeout)`` raising
    ``queue.Empty``, ``get_nowait``) so the drain loop is unchanged."""

    def __init__(self, maxsize: int = 0):
        self.maxsize = int(maxsize)  # 0 = unbounded (legacy behavior)
        self._interactive: deque = deque()
        self._batch: deque = deque()
        self._cond = threading.Condition()

    def __len__(self) -> int:
        with self._cond:
            return len(self._interactive) + len(self._batch)

    def try_put(self, item, *, slo: str = "interactive") -> bool:
        with self._cond:
            if self.maxsize > 0 and (
                len(self._interactive) + len(self._batch) >= self.maxsize
            ):
                return False
            (self._interactive if slo == "interactive" else self._batch).append(
                item
            )
            self._cond.notify()
            return True

    def _pop_locked(self):
        if self._interactive:
            return self._interactive.popleft()
        return self._batch.popleft()

    def get(self, timeout: float | None = None):
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not (self._interactive or self._batch):
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise queue.Empty
                self._cond.wait(remaining)
            return self._pop_locked()

    def get_nowait(self):
        with self._cond:
            if not (self._interactive or self._batch):
                raise queue.Empty
            return self._pop_locked()


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------


def fingerprint_array(A) -> str:
    """Content hash of one array: sha1 over (shape, dtype, C-contiguous
    bytes) — the cache-key primitive for spec payloads."""
    A = np.ascontiguousarray(np.asarray(A))
    h = hashlib.sha1()
    h.update(str(A.shape).encode())
    h.update(str(A.dtype).encode())
    h.update(A.tobytes())
    return h.hexdigest()


def _grid_key(grid) -> tuple | None:
    """Hashable form of a PathSpec grid — None = uncacheable spelling.
    Distinct spellings of the same auto grid (None vs {"auto": 20}) key
    differently; that only costs a cache miss, never a wrong hit."""
    if grid is None:
        return ("auto", None)
    if isinstance(grid, (int, np.integer)):
        return ("auto", int(grid))
    if isinstance(grid, dict):
        if set(grid) != {"auto"}:
            return None
        return ("auto", int(grid["auto"]))
    try:
        return ("grid",) + tuple(
            float(v) for v in np.asarray(list(grid), dtype=float).ravel()
        )
    except (TypeError, ValueError):
        return None


def _opts_key(opts) -> tuple | None:
    """Hashable form of criterion_opts — None = uncacheable (non-primitive
    values)."""
    if opts is None:
        return ()
    try:
        items = tuple(sorted((str(k), v) for k, v in dict(opts).items()))
        hash(items)
        return items
    except (TypeError, ValueError):
        return None


def spec_cache_key(spec, output: str) -> tuple | None:
    """Hashable cache key for a spec + resolved output — or None when the
    request is uncacheable (named sessions mutate; custom stream configs
    may reorder float accumulation, so only the default tiling caches).
    Path requests key on (payload fingerprint, grid, criterion + its
    parameters, output)."""
    if isinstance(spec, PathSpec):
        if spec.stream is not None:
            return None
        gk = _grid_key(spec.grid)
        ok = _opts_key(spec.criterion_opts)
        if gk is None or ok is None:
            return None
        payload = spec.S if spec.S is not None else spec.X
        return (
            "path" if spec.S is not None else "path_data",
            fingerprint_array(payload),
            gk,
            spec.criterion,
            None if spec.n is None else int(spec.n),
            float(spec.gamma),
            ok,
            output,
        )
    if isinstance(spec, DenseSpec):
        return ("dense", fingerprint_array(spec.S), float(spec.lam), output)
    if isinstance(spec, DataSpec):
        if spec.session is not None or spec.stream is not None:
            return None
        return ("data", fingerprint_array(spec.X), float(spec.lam), output)
    if isinstance(spec, JointSpec):
        if spec.stream is not None:
            return None
        mats = spec.Ss if spec.Ss is not None else spec.Xs
        kind = "joint" if spec.Ss is not None else "joint_data"
        return (
            kind,
            tuple(fingerprint_array(M) for M in mats),
            float(spec.lam1),
            float(spec.lam2),
            spec.penalty,
            len(mats),
            output,
        )
    return None


class ResultCache:
    """Thread-safe LRU over finished results, keyed by ``spec_cache_key``.

    Sits ABOVE the process-global compiled-solver cache: a compiled-cache
    hit still screens/plans/dispatches; a result-cache hit returns the
    finished ``GlassoResult`` without touching the planner."""

    def __init__(self, maxsize: int = 64):
        self.maxsize = int(maxsize)
        self._data: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key):
        if key is None or self.maxsize <= 0:
            return None
        with self._lock:
            if key not in self._data:
                return None
            self._data.move_to_end(key)
            return self._data[key]

    def put(self, key, value) -> None:
        if key is None or self.maxsize <= 0 or value is None:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()


# ---------------------------------------------------------------------------
# deadline helper
# ---------------------------------------------------------------------------


def deadline_instant(meta: RequestMeta | None) -> float | None:
    """Absolute monotonic expiry for a request admitted NOW (None = never)."""
    if meta is None or meta.deadline is None:
        return None
    return time.monotonic() + float(meta.deadline)


@dataclass
class TenantBuckets:
    """Per-tenant bucket registry: ``quotas`` maps tenant -> Quota;
    ``default`` applies to unlisted tenants (None = unmetered)."""

    quotas: dict = field(default_factory=dict)
    default: Quota | None = None
    clock: object = time.monotonic

    def __post_init__(self):
        self._buckets: dict[str, TokenBucket] = {}
        self._lock = threading.Lock()

    def try_admit(self, tenant: str) -> bool:
        quota = self.quotas.get(tenant, self.default)
        if quota is None:
            return True
        with self._lock:
            bucket = self._buckets.get(tenant)
            if bucket is None or bucket.quota != quota:
                bucket = self._buckets[tenant] = TokenBucket(
                    quota, clock=self.clock
                )
        return bucket.try_acquire()

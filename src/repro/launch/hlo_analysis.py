"""Trip-count-weighted cost analysis of compiled (post-SPMD, per-device) HLO.

Why this exists: ``compiled.cost_analysis()`` counts every computation ONCE —
a jax.lax.scan over 80 layers contributes its body a single time (verified:
an 8-step scan reports exactly 1/8 the flops of its unrolled twin).  Scanned
layer stacks, microbatch accumulation loops, and SSM chunk scans are exactly
how this framework keeps HLO compact, so module-level cost analysis is off
by orders of magnitude.  Fortunately XLA annotates optimized while ops with
``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO text into computations, propagates execution
multiplicity through while/call/fusion/conditional edges, and accumulates:

  flops        2 * prod(result) * prod(contracted) per dot; prod(result) per
               arithmetic elementwise op; prod(operand) per reduce
  bytes        operand + result buffer bytes of top-level ops (fusion bodies
               excluded — their internals never touch HBM)
  collectives  result-buffer bytes of all-reduce / all-gather /
               reduce-scatter / all-to-all / collective-permute, by kind

All numbers are per-device (the module is post-partitioning).  Validated in
tests against cost_analysis on scan-free graphs and against the trip-count
identity on scanned ones.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "tanh", "log", "log-plus-one", "negate",
    "maximum", "minimum", "select", "sqrt", "rsqrt", "logistic", "sine",
    "cosine", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "abs", "sign", "atan2", "clamp", "erf",
}

_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s([\w\-]+)\(")


def _shapes(segment: str):
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype in _DTYPE_BYTES:
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            yield dtype, n


def _buf_bytes(segment: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shapes(segment))


def _elems(segment: str) -> int:
    return sum(n for _, n in _shapes(segment))


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list] = {}
        self.entry = None
        self.result_type: dict[str, str] = {}
        self.roots: dict[str, tuple] = {}
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            # computation header: "[ENTRY] %name (args...) -> ret {"
            # args may contain nested parens (tuple types), so key off the
            # "-> ... {" tail and take the first token as the name.
            if (
                stripped.endswith("{")
                and "->" in stripped
                and "=" not in stripped.split("(")[0]
            ):
                toks = stripped.split()
                is_entry = toks[0] == "ENTRY"
                name = (toks[1] if is_entry else toks[0]).lstrip("%").rstrip("(")
                # names may appear as "%name" or "%name.N (" fused together
                name = name.split("(")[0]
                cur = name
                self.comps[cur] = []
                if is_entry:
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                is_root, iname, type_str, opcode = m.groups()
                self.comps[cur].append((iname, type_str, opcode, line))
                self.result_type[iname] = type_str
                if is_root:
                    self.roots[cur] = (iname, type_str, opcode, line)

    # ------------------------------------------------------- multiplicity
    def multiplicities(self) -> dict[str, float]:
        mult: dict[str, float] = defaultdict(float)
        fusion_bodies: set[str] = set()
        mult[self.entry] = 1.0
        order = [self.entry]
        seen = {self.entry}
        # breadth-first over call edges; HLO call graphs are acyclic
        i = 0
        while i < len(order):
            comp = order[i]
            i += 1
            for iname, type_str, opcode, line in self.comps.get(comp, []):
                targets: list[tuple[str, float]] = []
                if opcode == "while":
                    trip = 1.0
                    mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                    if mt:
                        trip = float(mt.group(1))
                    mb = re.search(r"body=(%?[\w\.\-]+)", line)
                    mc = re.search(r"condition=(%?[\w\.\-]+)", line)
                    if mb:
                        targets.append((mb.group(1).lstrip("%"), trip))
                    if mc:
                        targets.append((mc.group(1).lstrip("%"), trip + 1))
                elif opcode == "fusion":
                    mf = re.search(r"calls=(%?[\w\.\-]+)", line)
                    if mf:
                        body = mf.group(1).lstrip("%")
                        fusion_bodies.add(body)
                        targets.append((body, 1.0))
                elif opcode == "conditional":
                    for mm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=(%?[\w\.\-]+)|false_computation=(%?[\w\.\-]+))", line):
                        blob = mm.group(1) or ""
                        for b in blob.split(","):
                            b = b.strip().lstrip("%")
                            if b:
                                targets.append((b, 1.0))
                        for g in (mm.group(2), mm.group(3)):
                            if g:
                                targets.append((g.lstrip("%"), 1.0))
                else:
                    mt = re.search(r"to_apply=(%?[\w\.\-]+)", line)
                    if mt:
                        # reduce/sort/map/scatter scalar bodies: negligible,
                        # but keep the edge for completeness
                        targets.append((mt.group(1).lstrip("%"), 1.0))
                    mc2 = re.search(r"calls=(%?[\w\.\-]+)", line)
                    if mc2 and opcode == "call":
                        targets.append((mc2.group(1).lstrip("%"), 1.0))
                for tname, factor in targets:
                    if tname in self.comps:
                        mult[tname] += mult[comp] * factor
                        if tname not in seen:
                            seen.add(tname)
                            order.append(tname)
        self._fusion_bodies = fusion_bodies
        return dict(mult)

    # ------------------------------------------------------------- costs
    def _dot_flops(self, comp: str, type_str: str, line: str) -> float:
        res_elems = _elems(type_str)
        mdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        # lhs operand = first top-level argument of "dot(...)".  Newer XLA
        # prints bare refs ("dot(%a, %b)"), older (<=0.4.x) prints the type
        # inline ("dot(f32[128,256]{1,0} %a, ...)") — accept both: prefer the
        # ref's recorded result type, fall back to the inline segment.
        lhs = re.search(
            r"\bdot\(\s*(?:(\w+\[[\d,]*\](?:\{[\d,:TS()]*\})?)\s+)?(%?[\w\.\-]+)",
            line,
        )
        lhs_type = self.result_type.get(lhs.group(2), "") if lhs else ""
        if not _SHAPE_RE.search(lhs_type) and lhs and lhs.group(1):
            lhs_type = lhs.group(1)
        contract = 1
        if mdim:
            dims_m = _SHAPE_RE.search(lhs_type)
            if dims_m and dims_m.group(2):
                lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
                for ci in mdim.group(1).split(","):
                    if ci != "":
                        contract *= lhs_dims[int(ci)]
        return 2.0 * res_elems * contract

    def _operand_bytes_list(self, line: str) -> list:
        m = re.search(r"\((.*)\)", line)
        if not m:
            return []
        return [
            _buf_bytes(self.result_type.get(ref, ""))
            for ref in re.findall(r"%[\w\.\-]+", m.group(1))
        ]

    def _operand_bytes(self, line: str) -> int:
        return sum(self._operand_bytes_list(line))

    def _fusion_io_bytes(self, line: str, type_str: str) -> int:
        """Fusion IO with in-place/windowed patterns recognized.

        A fusion parameter that the body only touches through slicing ops
        (dynamic-slice / slice / gather / DUS target) costs its *window*
        bytes, not the whole buffer — otherwise every per-layer KV-cache
        read/write bills the entire stacked cache (observed 10x bytes
        inflation on decode cells).  A root dynamic-update-slice aliases its
        target, so the result is free (window already charged)."""
        mf = re.search(r"calls=(%?[\w\.\-]+)", line)
        body = mf.group(1).lstrip("%") if mf else None
        instrs = self.comps.get(body, []) if body else []
        root = self.roots.get(body) if body else None

        # def-map inside the body; chase convert/bitcast/copy chains — the
        # CPU backend emulates bf16 by wrapping real ops in f32 converts,
        # which must not hide the in-place structure (absent on real TPU).
        defs = {iname: (t, op, line) for iname, t, op, line in instrs}

        def chase(name):
            seen = 0
            while name in defs and defs[name][1] in ("convert", "bitcast", "copy") and seen < 8:
                refs = re.findall(r"%[\w\.\-]+", defs[name][2].split("(", 1)[1])
                if not refs:
                    break
                name = refs[0]
                seen += 1
            return name

        ordinal: dict[str, int] = {}
        for iname, t, op, line in instrs:
            if op == "parameter":
                mo = re.search(r"parameter\((\d+)\)", line)
                if mo:
                    ordinal[iname] = int(mo.group(1))

        def as_param(ref):
            return ordinal.get(chase(ref))

        windowed: dict[int, float] = {}
        full_use: set = set()
        aliased: set = set()
        for iname, t, op, line in instrs:
            if op in ("parameter", "convert", "bitcast", "copy"):
                continue
            refs = re.findall(r"%[\w\.\-]+", line.split("(", 1)[1] if "(" in line else "")
            if op in ("dynamic-slice", "slice", "gather") and refs:
                o = as_param(refs[0])
                if o is not None:
                    windowed[o] = windowed.get(o, 0.0) + 2 * _buf_bytes(t)
                    refs = refs[1:]
            elif op == "dynamic-update-slice" and refs:
                o = as_param(refs[0])
                rb = self._operand_bytes_list(line)
                win = rb[1] if len(rb) > 1 else 0
                if o is not None:
                    windowed[o] = windowed.get(o, 0.0) + 2 * win
                    aliased.add(o)
                    refs = refs[1:]
            for r in refs:
                o = as_param(r)
                if o is not None:
                    full_use.add(o)

        ops_b = self._operand_bytes_list(line)
        total = 0.0
        for i, b in enumerate(ops_b):
            if i in windowed and i not in full_use:
                total += min(b, windowed[i])
            else:
                total += b
        root_is_dus = False
        if root is not None:
            root_is_dus = defs.get(chase(root[0]), ("", root[2], ""))[1] == "dynamic-update-slice"
        if not root_is_dus:
            total += _buf_bytes(type_str)
        return int(total)

    def analyze(self) -> dict:
        mult = self.multiplicities()
        flops = 0.0
        bytes_accessed = 0.0
        coll = defaultdict(float)
        coll_counts = defaultdict(float)
        flops_by_op = defaultdict(float)
        for comp, instrs in self.comps.items():
            w = mult.get(comp, 0.0)
            if w == 0.0:
                continue
            in_fusion = comp in getattr(self, "_fusion_bodies", set())
            for iname, type_str, opcode, line in instrs:
                if opcode in _FREE:
                    continue
                # ---- flops (inside fusions too — they still execute)
                if opcode == "dot":
                    f = self._dot_flops(comp, type_str, line)
                    flops += w * f
                    flops_by_op["dot"] += w * f
                elif opcode in _ELEMENTWISE:
                    f = float(_elems(type_str))
                    flops += w * f
                    flops_by_op["elementwise"] += w * f
                elif opcode == "reduce":
                    f = float(self._operand_bytes(line)) / 4.0  # ~elements
                    flops += w * f
                    flops_by_op["reduce"] += w * f
                elif opcode == "convolution":
                    # not used by these models; coarse: 2 * out * window
                    f = 2.0 * _elems(type_str)
                    flops += w * f
                    flops_by_op["conv"] += w * f
                # ---- bytes (top-level ops only; fusion internals are free).
                # Opcode-aware so in-place/windowed ops aren't charged their
                # whole operand buffers (a decode step would otherwise look
                # like it re-reads the entire KV cache per layer slice).
                if not in_fusion:
                    if opcode in ("while", "conditional", "call", "tuple",
                                  "get-tuple-element", "reshape", "bitcast",
                                  "parameter", "constant"):
                        pass  # control flow & aliasing: no real traffic
                    elif opcode in ("dynamic-slice", "slice", "gather",
                                    "broadcast", "iota"):
                        bytes_accessed += w * 2 * _buf_bytes(type_str)
                    elif opcode == "dynamic-update-slice":
                        ops_b = self._operand_bytes_list(line)
                        upd = ops_b[1] if len(ops_b) > 1 else 0
                        bytes_accessed += w * 2 * upd  # read+write the window
                    elif opcode == "scatter":
                        ops_b = self._operand_bytes_list(line)
                        upd = ops_b[2] if len(ops_b) > 2 else _buf_bytes(type_str)
                        bytes_accessed += w * 2 * upd
                    elif opcode == "fusion":
                        bytes_accessed += w * self._fusion_io_bytes(line, type_str)
                    else:
                        bytes_accessed += w * (
                            _buf_bytes(type_str) + self._operand_bytes(line)
                        )
                # ---- collectives
                base = opcode.replace("-start", "")
                if base in _COLLECTIVES and not opcode.endswith("-done"):
                    b = float(_buf_bytes(type_str))
                    coll[base] += w * b
                    coll_counts[base + "_count"] += w
        out = dict(coll)
        out.update(coll_counts)
        out["total"] = sum(coll.values())
        return {
            "flops": flops,
            "bytes": bytes_accessed,
            "collective": out,
            "flops_by_op": dict(flops_by_op),
        }


def analyze_hlo(text: str) -> dict:
    return HloModule(text).analyze()


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat shim: trip-count-weighted collective bytes by kind."""
    return analyze_hlo(hlo_text)["collective"]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 faked host devices, proving the distribution config is coherent, and
capture the roofline inputs (memory analysis, cost analysis, collective
bytes) to a JSON per cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, before ANY other import.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--jobs 1]       # spawn one subprocess per cell
  python -m repro.launch.dryrun --report               # summarize existing JSONs
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, microbatches: int = 8,
             remat: str = "full", fsdp: bool = True, extra_tag: str = "",
             overrides: dict | None = None, batch_replicated: bool = False) -> dict:
    import jax

    from repro.configs.base import SHAPES, cell_supported, get_arch
    from repro.data.specs import input_specs
    from repro.distributed import context as dist_ctx
    from repro.distributed.sharding import ShardingPolicy
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.models.zoo import active_params, build_model, count_params_abstract
    from repro.optim import adamw
    from repro.train.state import init_state
    from repro.train.step import make_train_step

    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "microbatches": microbatches, "remat": remat,
        "fsdp": fsdp, "tag": extra_tag, "overrides": overrides or {},
        "batch_replicated": batch_replicated,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    policy = ShardingPolicy(mesh, fsdp=fsdp, batch_replicated=batch_replicated)
    model = build_model(cfg)
    optimizer = adamw()
    key = jax.random.key(0)

    # -- abstract state + specs (box trick: specs are static python)
    box = {}

    def _state_fn(k):
        st, specs = init_state(model, optimizer, k)
        box["specs"] = specs
        return st

    t0 = time.perf_counter()
    if shape.kind == "train":
        state_sds = jax.eval_shape(_state_fn, key)
        specs = box["specs"]
        pspec = policy.param_shardings(specs, state_sds.params)
        state_sh = type(state_sds)(
            step=policy.replicated(),
            params=pspec,
            opt_state={"m": pspec, "v": pspec},
        )
        batch_sds = input_specs(cfg, shape)
        batch_sh = policy.batch_shardings(batch_sds)
        step_fn = make_train_step(
            model, optimizer, microbatches=microbatches, remat=remat,
            sharding_policy=policy,
        )
        with mesh, dist_ctx.activate(policy):
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
            ).lower(state_sds, batch_sds)
    else:
        def _params_fn(k):
            p, s = model.init(k)
            box["specs"] = s
            return p

        params_sds = jax.eval_shape(_params_fn, key)
        specs = box["specs"]
        params_sh = policy.param_shardings(specs, params_sds)
        if shape.kind == "prefill":
            batch_sds = input_specs(cfg, shape)
            batch_sh = policy.batch_shardings(batch_sds)
            with mesh, dist_ctx.activate(policy):
                lowered = jax.jit(
                    lambda p, b: model.prefill(p, b),
                    in_shardings=(params_sh, batch_sh),
                ).lower(params_sds, batch_sds)
        else:  # decode
            ins = input_specs(cfg, shape)
            token_sh = policy.batch_shardings(ins["token"])
            caches_sh = policy.cache_shardings(ins["caches"])
            with mesh, dist_ctx.activate(policy):
                lowered = jax.jit(
                    lambda p, t, c, pos: model.decode_step(p, t, c, pos),
                    in_shardings=(params_sh, token_sh, caches_sh, policy.replicated()),
                    donate_argnums=(2,),
                ).lower(params_sds, ins["token"], ins["caches"], ins["pos"])

    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-weighted analysis (module-level cost_analysis counts scan
    # bodies once — see hlo_analysis docstring)
    weighted = analyze_hlo(hlo)
    coll = weighted["collective"]

    n_chips = 512 if mesh_kind == "multi" else 256
    rec.update(
        status="ok",
        seconds_lower=round(t_lower, 2),
        seconds_compile=round(t_compile, 2),
        chips=n_chips,
        params_total=count_params_abstract(cfg),
        params_active=active_params(cfg),
        flops_per_device=float(weighted["flops"]),
        bytes_per_device=float(weighted["bytes"]),
        flops_by_op=weighted["flops_by_op"],
        xla_cost_analysis={
            "flops_unweighted": float(ca.get("flops", -1.0)),
            "bytes_unweighted": float(ca.get("bytes accessed", -1.0)),
        },
        collective=coll,
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        tokens_global=shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len),
    )
    # proof-of-fit line, as the assignment asks
    print(f"[{cfg.name} x {shape_name} x {mesh_kind}] memory_analysis:", ma)
    print(f"[{cfg.name} x {shape_name} x {mesh_kind}] cost_analysis: "
          f"flops={rec['flops_per_device']:.3e} bytes={rec['bytes_per_device']:.3e} "
          f"collective={coll.get('total', 0):.3e}")
    return rec


def cell_path(arch, shape, mesh_kind, tag="") -> Path:
    suffix = f"_{tag}" if tag else ""
    return OUT_DIR / f"{arch.replace('.', '_')}__{shape}__{mesh_kind}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--serve-batch-replicated", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/float/bool parsed)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.report:
        for f in sorted(OUT_DIR.glob("*.json")):
            rec = json.loads(f.read_text())
            print(f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} {rec['status']}")
        return

    if args.all:
        from repro.configs.base import ARCH_IDS, SHAPES

        cells = [
            (a, s, m)
            for a in ARCH_IDS
            for s in SHAPES
            for m in ("single", "multi")
        ]
        for a, s, m in cells:
            out = cell_path(a, s, m, args.tag)
            if out.exists() and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--microbatches", str(args.microbatches),
                   "--remat", args.remat, "--tag", args.tag]
            if args.no_fsdp:
                cmd.append("--no-fsdp")
            if args.force:
                cmd.append("--force")
            print(">>>", " ".join(cmd), flush=True)
            subprocess.run(cmd, check=False)
        return

    out = cell_path(args.arch, args.shape, args.mesh, args.tag)
    if out.exists() and not args.force:
        print(f"exists: {out}")
        return
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False}.get(v.lower(), v)
        overrides[k] = v
    try:
        rec = run_cell(
            args.arch, args.shape, args.mesh,
            microbatches=args.microbatches, remat=args.remat,
            fsdp=not args.no_fsdp, extra_tag=args.tag,
            overrides=overrides or None,
            batch_replicated=args.serve_batch_replicated,
        )
    except Exception as e:  # record failures — they are bugs to fix
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "error": f"{type(e).__name__}: {e}",
        }
        out.write_text(json.dumps(rec, indent=2))
        raise
    out.write_text(json.dumps(rec, indent=2))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Batched graphical-lasso serving: many concurrent (S, lam) requests, one
coalesced solver stream — the ROADMAP's "heavy traffic" workload for the
Theorem-1 pipeline.

Theorem 1 makes every request a bag of INDEPENDENT padded blocks, and the
engine's executor already batches same-size blocks; serving just widens the
batch axis across requests.  The batcher thread drains the queue, screens and
plans each request through the engine registry/planner, then regroups every
(request, bucket) by padded size and dispatches ONE compiled solver call per
size with a per-block lambda vector — so requests with different lambdas, or
different matrices, share executables AND batches.  The compiled cache is the
executor's process-global one: after warm-up, a steady-state mix of request
shapes runs with zero compiles (watch ``executor.compiled_hit``).

Structure routing (DESIGN.md Section 9) extends this in two ways.  Inside a
batch, buckets are coalesced per (padded size, route): closed-form buckets
share one batched forest-kernel call, chordal buckets are solved directly on
the host, and only the iterative remainder pays solver iterations — all
verified with iterative fallback, exactly like the engine executor.  And at
ADMISSION, a request whose plan is entirely fast-path (no "general" bucket)
is solved synchronously on the caller's thread and NEVER ENTERS the dispatch
queue: a microseconds-cheap closed-form solve should not wait out the
batching window behind an iterative co-traveller.

    PYTHONPATH=src python -m repro.launch.serve_glasso --requests 8 --p 60

THE CONTROL PLANE (DESIGN.md Section 14; ``launch.control_plane``): every
admission verb is one — ``submit(spec, meta=RequestMeta(...))`` — where the
spec says WHAT to solve (``DenseSpec(S, lam)`` / ``DataSpec(X, lam,
session=...)`` / ``JointSpec(Ss=..., lam1=..., lam2=...)``) and the meta says
HOW to treat it: ``tenant`` charges a per-tenant token bucket (``quotas=`` /
``default_quota=``; exhausted buckets raise a typed ``Overload`` from submit,
reason="quota"); ``slo="interactive"`` keeps the admission fast path and
dequeues ahead of every "batch" request, ``slo="batch"`` is best-effort and
yields both; ``deadline`` (relative seconds) drops the request BEFORE
dispatch with ``DeadlineExceeded`` once expired — a dead request never burns
a solver.  ``max_queue=`` bounds the dispatch queue (full = ``Overload``
reason="queue", raised synchronously — no future that hangs a timeout), and
``result_cache=`` adds an LRU over finished results keyed by (payload
fingerprint, lambdas, penalty, K, output) ABOVE the process-global compiled
cache: an identical re-submission returns the finished result with zero
planner work.  The historical verbs — ``submit(S, lam)``, ``submit_data``,
``submit_joint`` — still work as deprecated shims over the same chokepoint.

DATA-MATRIX ADMISSION (``DataSpec``) accepts the raw (n, p) X instead of
a covariance: screening runs out-of-core through ``repro.stream`` (the dense
S never exists — materialized per-component blocks flow through the same
planner/batcher), and a named ``session`` pins the screen state so
``append_rows`` can absorb rank-k data updates INCREMENTALLY: only tiles
whose perturbation certificate broke are re-screened, affected components
merge/split, and the fresh solve warm-starts from the session's previous
solution (untouched components start essentially converged — the serving
analog of the path warm start).

PATH ADMISSION (``PathSpec``) turns the server into a model-selection
service: ``submit(PathSpec(S=S, grid={"auto": 20}, criterion="ebic",
n=...))`` (or ``X=`` for the out-of-core form, required by the resampling
criteria "cv"/"stars") runs the warm-started homotopy path over the whole
descending grid on the batcher thread via ``repro.select.select_path`` —
LITERALLY that function, so the served ``Selection`` (selected graph +
per-lambda diagnostics + warm-start accounting) is bitwise identical to
the offline call on the same inputs.  Path requests default to the
"batch" SLO (a grid of solves should not jump interactive co-travellers;
an explicit ``RequestMeta(slo="interactive")`` overrides), never take the
admission fast path, and cache by (payload fingerprint, grid, criterion +
parameters, output) like every other cacheable kind.

JOINT ADMISSION (``JointSpec``) accepts K class covariances (or K data
matrices via ``Xs=``) estimated jointly under the fused/group penalty
(``repro.joint``): the exact hybrid thresholding screen and the joint plan
run on the caller's thread, an all-closed-form plan (singletons +
identical-block forest components) solves synchronously at admission, and
everything else queues for the batcher, which dispatches joint buckets
through the shared compiled cache (keys gain K, so a steady-state mix of
single-class and joint traffic compiles nothing).

COUNTER NAMESPACES surfaced by ``serve_stats()``: the complete name-by-name
table (sum vs peak semantics, units, which layer bumps what) lives in
DESIGN.md Section 17 next to the metric/label taxonomy.  The counters are
flat entries in the process-global ``repro.obs`` registry, so every name in
that table is also exported verbatim — dots sanitized to underscores — by
``GlassoServer.metrics()`` (Prometheus text exposition) alongside the
labeled ``serve.request_seconds`` latency histogram.

OBSERVABILITY (DESIGN.md Section 17; ``repro.obs``): every admitted request
carries a ``Trace`` rooted at ``serve.request`` (attrs: tenant, slo, kind).
Admission-time work — screen, plan, the synchronous fast path — records
spans on the caller's thread; queued work re-enters the request's trace on
the batcher thread through the EXPLICIT token handoff (``activate``; the
contextvar does not follow the queue), and the finished trace rides both
the result (``result.trace``) and the future (``future.trace``).  Export
one with ``trace.to_chrome_json(path)`` and open it in Perfetto /
chrome://tracing.  Request latency (admission to future resolution) lands
in the ``serve.request_seconds`` histogram labeled (tenant, slo, kind in
{dense, data, joint, path, session}), so the server itself answers
p50/p99-per-tenant questions: ``REGISTRY.quantile("serve.request_seconds",
0.99, slo="interactive")``.  One attribution rule: a COALESCED solver
dispatch serves many requests at once and is therefore never recorded in
any single request's trace — per-request spans cover plan and assembly;
the shared dispatch stays visible in ``engine.dispatch.*``.

SPARSE RESULTS (``output=``): the server-level ``output`` ("dense" /
"sparse" / "auto", default "auto") picks the result representation for
every admission path, and each request can override it via
``RequestMeta(output=...)``.  "auto" resolves per request from its p
(sparse above ``core.sparse.AUTO_SPARSE_P``); a sparse result's ``Theta``
is a ``SparseTheta`` / ``JointSparseTheta`` — per-component padded block
stacks, edge lists via ``support_edges()``, CSR via ``to_csr()`` —
assembled with ZERO (p, p) allocation, so serving payloads for huge
requests stay O(sum b_i^2).

OVERSIZE ADMISSION (``oversize_threshold`` / ``oversize_budget_mb`` on
``EngineOptions``): a request whose screen leaves a component past the
single-device block cap is still admitted — the planner classes it
"oversize", the admission fast path declines it (a mesh-wide solve is not
microseconds-cheap), and the batcher dispatches it down the executor's
sharded route: shard-direct gather, the mesh-spanning no-eigh ADMM,
distributed KKT verification, single-device iterative fallback on
rejection.  ``GlassoResult.oversize`` carries the per-request
{dispatched, inner_iters, fallbacks}.
"""

from __future__ import annotations

import argparse
import queue
import threading
import time
import warnings
from concurrent.futures import Future
from dataclasses import dataclass, field, replace

import numpy as np

from contextlib import nullcontext

from repro.core.instrument import bump, counts, timed_dispatch
from repro.obs.metrics import REGISTRY
from repro.obs.trace import Trace, activate, span
from repro.launch.control_plane import (
    AdmissionQueue,
    DataSpec,
    DeadlineExceeded,
    DenseSpec,
    JointSpec,
    Overload,
    PathSpec,
    RequestMeta,
    ResultCache,
    TenantBuckets,
    deadline_instant,
    spec_cache_key,
)

_LEGACY_VERB_MSG = (
    "{verb} is deprecated; pass a typed spec — "
    "server.submit({spec}, meta=RequestMeta(tenant=..., slo=..., "
    "deadline=..., output=...)) — see launch.control_plane"
)


@dataclass
class GlassoRequest:
    # dense ndarray, or a stream.MaterializedCovariance for data requests
    # (both satisfy the blocks.py gather protocol the batcher uses)
    S: object
    lam: float
    future: Future = field(default_factory=Future)
    # screen/plan results computed at fast-path admission; reused by the
    # batcher so a queued request is never planned twice
    labels: np.ndarray | None = None
    stats: object = None
    plan: object = None
    # resolved result representation ("dense" | "sparse"), fixed at admission
    output: str = "dense"
    # control-plane identity: accounting tenant, SLO class, and the absolute
    # monotonic expiry (None = never) fixed at admission
    tenant: str = "default"
    slo: str = "interactive"
    deadline_at: float | None = None
    # per-request obs.Trace (None when the server runs trace=False); the
    # batcher re-enters it via _req_scope — the explicit thread handoff
    trace: object = None


@dataclass
class JointRequest:
    """A K-class joint request (``JointSpec``); rides the same queue and
    shutdown drain as plain requests."""

    Ss: object                     # list of dense arrays or materialized covs
    lam1: float
    lam2: float
    penalty: str
    future: Future = field(default_factory=Future)
    labels: np.ndarray | None = None
    stats: object = None
    plan: object = None
    output: str = "dense"
    tenant: str = "default"
    slo: str = "interactive"
    deadline_at: float | None = None
    trace: object = None


@dataclass
class PathRequest:
    """A model-selection request (``PathSpec``): the whole homotopy grid +
    criterion resolve on the batcher thread via ``repro.select.
    select_path`` — literally that function, so a served selection is
    bitwise identical to the offline call on the same inputs/options.
    Rides the same queue, deadline expiry, and shutdown drain as every
    other request kind; never takes the admission fast path (a grid of
    solves is not microseconds-cheap) and defaults to the "batch" SLO."""

    spec: PathSpec
    future: Future = field(default_factory=Future)
    output: str = "dense"
    tenant: str = "default"
    slo: str = "batch"
    deadline_at: float | None = None
    trace: object = None


def _request_kind(spec) -> str:
    """The histogram/trace ``kind`` label for one admission spec."""
    if isinstance(spec, DenseSpec):
        return "dense"
    if isinstance(spec, DataSpec):
        return "data"
    if isinstance(spec, PathSpec):
        return "path"
    return "joint"


def _req_scope(req):
    """Re-enter a queued request's trace on the batcher thread.

    The explicit cross-thread handoff from DESIGN.md Section 17: the
    contextvar does not follow the queue, and implicit inheritance would
    pin every batcher span to whichever request started the thread."""
    tr = getattr(req, "trace", None)
    if tr is None:
        return nullcontext()
    return activate((tr, tr.root_id))


@dataclass
class _SessionEntry:
    session: object                # stream.DataSession
    last: Future | None = None     # most recent solve (warm-start source)
    # serializes append_rows per session: the warm-start read and the
    # `last` write must be one transaction, and DataSession state must not
    # interleave between concurrent appends
    lock: threading.Lock = field(default_factory=threading.Lock)


@dataclass
class _PlacedBucket:
    request: "GlassoRequest"
    plan: object
    bucket: object


class GlassoServer:
    """Coalescing batch server over the engine executor.

    ``submit(spec, meta=...)`` is thread-safe and returns a Future resolving
    to the engine's ``GlassoResult`` (or raises ``Overload`` synchronously
    when the control plane refuses admission).  ``max_delay`` is the
    batching window: the batcher waits that long after the first queued
    request for co-travellers before dispatching (classic serving
    latency/throughput knob).

    Engine configuration travels as ``options=EngineOptions(...)`` — the
    same typed object ``glasso``/``joint_glasso`` accept; legacy bare
    engine kwargs (``solver=``, ``route=``, ``tol=``, ...) still normalize
    through the shared chokepoint.  Control-plane knobs are the server's
    own: ``quotas`` (tenant -> ``control_plane.Quota``), ``default_quota``
    (unlisted tenants; None = unmetered), ``max_queue`` (0 = unbounded),
    ``result_cache`` (LRU entries; 0 = off — fingerprinting a request
    costs one sha1 pass over its payload, so caching is opt-in)."""

    def __init__(
        self,
        *,
        options=None,
        max_delay: float = 0.005,
        max_batch: int = 64,
        fast_path: bool = True,
        quotas: dict | None = None,
        default_quota=None,
        max_queue: int = 0,
        result_cache: int = 0,
        **legacy_engine_kwargs,
    ):
        from repro.core.solvers import SOLVERS
        from repro.engine.api import resolve_oversize
        from repro.engine.executor import BucketExecutor, _validate_solver_opts
        from repro.engine.options import normalize_options

        opts = normalize_options(
            options, legacy_engine_kwargs, context="GlassoServer"
        )
        solver = opts.resolved_solver("bcd")
        if solver not in SOLVERS:
            raise ValueError(
                f"unknown solver {solver!r}; available: {sorted(SOLVERS)}"
            )
        solver_opts = dict(opts.solver_opts)
        _validate_solver_opts(solver, solver_opts)
        self.options = opts
        self.solver = solver
        self.output = opts.output
        self.dtype = opts.resolved_dtype()
        self.cc_backend = opts.cc_backend
        self.max_delay = max_delay
        self.max_batch = max_batch
        self.route = opts.route
        self.fast_path = fast_path and opts.route
        self.route_check_tol = opts.route_check_tol
        # single-device block cap: larger components are ADMITTED (not
        # rejected) and routed down the mesh-spanning sharded path by the
        # batcher — an oversize request just never takes the synchronous
        # admission fast path (a mesh-wide solve is not "microseconds-cheap")
        self.oversize = resolve_oversize(
            opts.oversize_threshold, opts.oversize_budget_mb,
            opts.np_dtype(), route=opts.route,
        )
        self.solver_opts = solver_opts
        self._opts_key = tuple(sorted(solver_opts.items()))
        # admission-time fast-path solver: a stateless ladder executor (the
        # compiled cache underneath is process-global and shared with the
        # batcher's dispatches)
        self._fast_executor = BucketExecutor(
            solver=solver,
            dtype=self.dtype,
            solver_opts=dict(solver_opts),
            route=True,
            route_check_tol=self.route_check_tol,
            jax_annotations=opts.trace == "jax",
        )
        # data sessions: named streaming-screen states for append_rows; the
        # session executor honors the server's route setting (the admission
        # fast-path executor is route=True by definition)
        self._session_executor = BucketExecutor(
            solver=solver,
            dtype=self.dtype,
            solver_opts=dict(solver_opts),
            route=opts.route,
            route_check_tol=self.route_check_tol,
            jax_annotations=opts.trace == "jax",
        )
        self._sessions: dict[str, _SessionEntry] = {}
        self._sessions_lock = threading.Lock()
        # control plane: per-tenant token buckets, the bounded two-class
        # priority queue, and the finished-result LRU
        self._quotas = TenantBuckets(
            quotas=dict(quotas or {}), default=default_quota
        )
        self._queue = AdmissionQueue(maxsize=max_queue)
        self._cache = ResultCache(result_cache)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._joint = None  # lazily-built JointEngine (repro.joint)

    def _joint_engine(self):
        """The server's shared K-class engine (``repro.joint.JointEngine``).

        Built lazily so single-class servers never import the joint stack.
        Solver options are the intersection of the server's opts with what
        ``joint_admm`` accepts (tol/max_iter/rho travel; bcd-specific knobs
        do not)."""
        if self._joint is None:
            import inspect

            from repro.joint.admm import joint_admm
            from repro.joint.engine import JointEngine

            accepted = set(inspect.signature(joint_admm).parameters)
            joint_opts = self.options.replace(
                solver=None,  # JointEngine resolves its own default
                oversize_threshold=None,
                oversize_budget_mb=None,
                solver_opts={
                    k: v for k, v in self.solver_opts.items() if k in accepted
                },
            )
            self._joint = JointEngine(options=joint_opts)
        return self._joint

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "GlassoServer":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
        self._fail_pending()

    def _fail_pending(self) -> None:
        """Fail queued requests fast instead of letting their clients block
        out the full result() timeout.  Called from stop() and from the
        admission chokepoint when an enqueue loses the shutdown race."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if not req.future.done():
                req.future.set_exception(RuntimeError("GlassoServer stopped"))

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- client API --------------------------------------------------------

    def _resolve_output(self, output: str | None, p: int) -> str:
        """Fix a request's result representation at admission: the request
        ``meta.output`` overrides the server default; "auto" resolves from
        p."""
        from repro.core.sparse import resolve_output

        return resolve_output(self.output if output is None else output, p)

    @staticmethod
    def _fold_output(
        meta: RequestMeta | None, output: str | None, *, spec=None
    ) -> RequestMeta:
        """Merge the legacy per-call ``output=`` kwarg into the meta.

        When the caller supplied no meta at all, the default SLO is spec-
        aware: path requests (``PathSpec``) admit as "batch" — a whole grid
        of solves should not jump interactive co-travellers — while every
        other kind keeps the historical "interactive" default.  An explicit
        ``RequestMeta(slo=...)`` always wins."""
        if meta is None:
            meta = RequestMeta(
                slo="batch" if isinstance(spec, PathSpec) else "interactive"
            )
        if output is None:
            return meta
        if meta.output is not None:
            raise TypeError(
                "output= conflicts with meta.output; set it in RequestMeta"
            )
        return replace(meta, output=output)

    def submit(
        self,
        spec,
        lam: float | None = None,
        *,
        output: str | None = None,
        meta: RequestMeta | None = None,
    ) -> Future:
        """Admit ONE request of any kind: ``submit(spec, meta=...)``.

        ``spec`` is a ``DenseSpec`` / ``DataSpec`` / ``JointSpec``
        (``launch.control_plane``); ``meta`` carries tenant, SLO class,
        deadline, and the per-request output override.  Returns a Future
        resolving to the engine result — or raises ``Overload``
        synchronously when the tenant's token bucket is dry or the bounded
        queue is full (backpressure is an exception, never a hung future).

        The historical form ``submit(S, lam)`` still works as a deprecated
        shim (one ``DeprecationWarning``) and is equivalent to
        ``submit(DenseSpec(S, lam))``."""
        if not isinstance(spec, (DenseSpec, DataSpec, JointSpec, PathSpec)):
            warnings.warn(
                _LEGACY_VERB_MSG.format(
                    verb="submit(S, lam)", spec="DenseSpec(S, lam)"
                ),
                DeprecationWarning,
                stacklevel=2,
            )
            if lam is None:
                raise TypeError("legacy submit(S, lam) needs lam")
            spec = DenseSpec(S=np.asarray(spec), lam=float(lam))
        elif lam is not None:
            raise TypeError(
                "submit(spec) takes no positional lam — it lives on the spec"
            )
        return self._submit(spec, self._fold_output(meta, output, spec=spec))

    def submit_data(
        self,
        X: np.ndarray,
        lam: float,
        *,
        session: str | None = None,
        stream=None,
        output: str | None = None,
    ) -> Future:
        """Deprecated shim: ``submit(DataSpec(X, lam, session=...,
        stream=...))`` — see that path for semantics."""
        warnings.warn(
            _LEGACY_VERB_MSG.format(
                verb="submit_data", spec="DataSpec(X, lam, session=...)"
            ),
            DeprecationWarning,
            stacklevel=2,
        )
        spec = DataSpec(X=X, lam=float(lam), session=session, stream=stream)
        return self._submit(spec, self._fold_output(None, output))

    def submit_joint(
        self,
        Ss=None,
        lam1: float | None = None,
        lam2: float = 0.0,
        *,
        penalty: str = "group",
        Xs=None,
        stream=None,
        output: str | None = None,
    ) -> Future:
        """Deprecated shim: ``submit(JointSpec(Ss=..., lam1=..., lam2=...))``
        — see that path for semantics."""
        warnings.warn(
            _LEGACY_VERB_MSG.format(
                verb="submit_joint", spec="JointSpec(Ss, lam1, lam2)"
            ),
            DeprecationWarning,
            stacklevel=2,
        )
        if lam1 is None:
            raise ValueError("submit_joint needs lam1")
        try:
            spec = JointSpec(
                Ss=Ss, lam1=float(lam1), lam2=float(lam2),
                penalty=penalty, Xs=Xs, stream=stream,
            )
        except ValueError as e:
            # legacy contract: malformed joint payloads fail via the future
            fut: Future = Future()
            fut.set_exception(e)
            return fut
        return self._submit(spec, self._fold_output(None, output))

    # -- the admission chokepoint ------------------------------------------

    def _submit(self, spec, meta: RequestMeta) -> Future:
        """Every admission path in one place: stop-check, result cache,
        tenant quota, then the spec-kind handoff.  Centralizing the
        stop-check here (plus the post-enqueue sweep in ``_enqueue``) is
        what closes the historical shutdown race where a data/joint
        admission could enqueue after ``stop()``'s drain and hang its
        client."""
        if self._stop.is_set():
            fut: Future = Future()
            fut.set_exception(RuntimeError("GlassoServer stopped"))
            return fut
        kind = _request_kind(spec)
        t_admit = time.perf_counter()
        out = self._resolve_output(meta.output, spec.p)
        key = spec_cache_key(spec, out) if self._cache.maxsize > 0 else None
        if key is not None:
            cached = self._cache.get(key)
            if cached is not None:
                bump("serve.requests")
                bump("serve.cache.hits")
                REGISTRY.observe(
                    "serve.request_seconds",
                    time.perf_counter() - t_admit,
                    tenant=meta.tenant, slo=meta.slo, kind=kind,
                )
                fut = Future()
                fut.set_result(cached)
                return fut
            bump("serve.cache.misses")
        if not self._quotas.try_admit(meta.tenant):
            bump("serve.rejected.quota")
            raise Overload(
                f"tenant {meta.tenant!r} exceeded its admission quota",
                reason="quota",
                tenant=meta.tenant,
            )
        bump("serve.requests")
        tr = (
            Trace("serve.request", tenant=meta.tenant, slo=meta.slo, kind=kind)
            if self.options.trace
            else None
        )
        # admission-time work (screen / plan / fast-path solve) records
        # spans on THIS thread; queued remainders re-enter via _req_scope
        with activate((tr, tr.root_id)) if tr is not None else nullcontext():
            if isinstance(spec, DenseSpec):
                fut = self._admit_dense(spec, meta, out, key, tr)
            elif isinstance(spec, DataSpec):
                fut = self._admit_data(spec, meta, out, key, tr)
            elif isinstance(spec, PathSpec):
                fut = self._admit_path(spec, meta, out, key, tr)
            else:
                fut = self._admit_joint(spec, meta, out, key, tr)
        self._finish_on_done(fut, tr, t_admit, kind, meta)
        return fut

    def _finish_on_done(
        self, fut: Future, tr, t_admit: float, kind: str, meta: RequestMeta
    ) -> None:
        """Terminal observability for one admitted request: the trace rides
        the future, and whichever thread resolves it closes the trace and
        records admission-to-resolution latency in the labeled
        ``serve.request_seconds`` histogram (errors included — a rejected
        dispatch is still a served request)."""
        if tr is not None:
            fut.trace = tr

        def _done(_f, tr=tr, t_admit=t_admit, kind=kind, meta=meta):
            if tr is not None:
                tr.finish()
            REGISTRY.observe(
                "serve.request_seconds",
                time.perf_counter() - t_admit,
                tenant=meta.tenant, slo=meta.slo, kind=kind,
            )

        fut.add_done_callback(_done)

    def _attach_cache_fill(self, fut: Future, key) -> None:
        """Write-through on success: a cacheable request's finished result
        lands in the LRU whichever path (fast path, batcher, repair) solved
        it."""
        if key is None:
            return

        def _fill(f: Future, key=key):
            try:
                if f.exception() is None:
                    self._cache.put(key, f.result())
            except Exception:  # pragma: no cover - cancelled futures
                pass

        fut.add_done_callback(_fill)

    def _enqueue(self, req) -> Future:
        """Bounded enqueue + the shutdown-race sweep, shared by every
        admission kind."""
        if not self._queue.try_put(req, slo=req.slo):
            bump("serve.rejected.queue")
            raise Overload(
                f"dispatch queue full (max_queue={self._queue.maxsize})",
                reason="queue",
                tenant=req.tenant,
            )
        if self._stop.is_set():
            # lost the race against stop(): its drain may have run before our
            # put landed, so sweep the queue ourselves
            self._fail_pending()
        return req.future

    def _admit_dense(self, spec: DenseSpec, meta, out: str, key, tr) -> Future:
        req = GlassoRequest(
            S=np.asarray(spec.S), lam=float(spec.lam), output=out,
            tenant=meta.tenant, slo=meta.slo,
            deadline_at=deadline_instant(meta), trace=tr,
        )
        self._attach_cache_fill(req.future, key)
        # the fast path is the interactive SLO's half of the contract: batch
        # requests always take the queue (and yield the window)
        if self.fast_path and meta.slo == "interactive":
            if self._try_fast_path(req):
                return req.future
        return self._enqueue(req)

    def _admit_data(self, spec: DataSpec, meta, out: str, key, tr) -> Future:
        """Data-matrix admission: the out-of-core screen runs on the
        caller's thread (``repro.stream``: tiled Gram + compacted edges +
        materialized per-component blocks — the dense S never exists), then
        the request takes the normal path: solved synchronously if every
        bucket routes non-iteratively (interactive only), queued otherwise.

        ``spec.session`` pins the streaming screen state so later
        ``append_rows(name, Y)`` calls re-screen incrementally; without it
        the screen runs stateless (no per-tile records, no retained X —
        nothing a one-shot request would ever use)."""
        from repro.engine.planner import build_plan_incremental
        from repro.stream import DataSession, stream_screen

        bump("serve.data_requests")
        req = GlassoRequest(
            S=None, lam=float(spec.lam), output=out,
            tenant=meta.tenant, slo=meta.slo,
            deadline_at=deadline_instant(meta), trace=tr,
        )
        self._attach_cache_fill(req.future, key)
        try:
            with span("serve.plan", source="data"):
                if spec.session is not None:
                    ses = DataSession(
                        spec.X, req.lam, config=spec.stream,
                        oversize=self.oversize,
                    )
                    req.S, req.labels, req.stats = ses.S, ses.labels, ses.stats
                    with self._sessions_lock:
                        self._sessions[spec.session] = _SessionEntry(
                            session=ses, last=req.future
                        )
                else:
                    sc = stream_screen(
                        spec.X, [req.lam], config=spec.stream,
                        oversize=self.oversize,
                    )
                    req.S, req.labels, req.stats = (
                        sc.S, sc.labels[0], sc.stats[0]
                    )
                req.plan, _ = build_plan_incremental(
                    req.S, req.lam, req.labels, classify_structures=self.route,
                    oversize=self.oversize,
                )
        except Exception as e:
            req.future.set_exception(e)
            return req.future
        if self.fast_path and meta.slo == "interactive":
            try:
                if self._solve_if_fastpath(req):
                    return req.future
            except Exception as e:  # pragma: no cover - defensive
                req.future.set_exception(e)
                return req.future
        return self._enqueue(req)

    def _admit_joint(self, spec: JointSpec, meta, out: str, key, tr) -> Future:
        """K-class joint admission (``repro.joint``): the exact hybrid
        thresholding screen and the joint plan run on the caller's thread;
        a plan whose every union bucket routes non-iteratively (singletons
        + identical-block forest components) is solved synchronously at
        admission (interactive only), everything else queues for the
        batcher.  Shutdown drains joint futures through the same
        ``_fail_pending`` path as every other request kind."""
        bump("joint.requests")
        req = JointRequest(
            Ss=None, lam1=float(spec.lam1), lam2=float(spec.lam2),
            penalty=spec.penalty, output=out,
            tenant=meta.tenant, slo=meta.slo,
            deadline_at=deadline_instant(meta), trace=tr,
        )
        self._attach_cache_fill(req.future, key)
        try:
            engine = self._joint_engine()
            with span("serve.plan", kind="joint"):
                if spec.Xs is not None:
                    from repro.joint.stream import joint_stream_screen

                    sc = joint_stream_screen(
                        spec.Xs, req.lam1, req.lam2, penalty=spec.penalty,
                        config=spec.stream,
                    )
                    req.Ss, req.labels, req.stats = sc.S, sc.labels, sc.stats
                else:
                    req.Ss = [np.asarray(S) for S in spec.Ss]
                    req.labels, req.stats = engine.screen(
                        req.Ss, req.lam1, req.lam2, penalty=spec.penalty
                    )
                req.plan = engine.plan(
                    req.Ss, req.lam1, req.lam2, req.labels,
                    penalty=spec.penalty,
                )
        except Exception as e:
            req.future.set_exception(e)
            return req.future
        if self.fast_path and meta.slo == "interactive":
            from repro.engine.registry import route_for

            if not any(
                route_for(b.structure) in ("iterative", "sharded")
                for b in req.plan.buckets
            ):
                try:
                    self._solve_joint_request(req)
                    bump("joint.fastpath_requests")
                    bump("serve.fastpath_requests")
                    return req.future
                except Exception as e:  # pragma: no cover - defensive
                    if not req.future.done():
                        req.future.set_exception(e)
                    return req.future
        return self._enqueue(req)

    def _admit_path(self, spec: PathSpec, meta, out: str, key, tr) -> Future:
        """Model-selection admission: validation already ran in the spec's
        ``__post_init__``; the homotopy grid + criterion run entirely on the
        batcher thread (``_solve_path_request``), so admission just queues.
        There is deliberately NO fast path — even an all-closed-form grid is
        n_points solves plus scoring, not a microseconds-cheap call."""
        bump("serve.path_requests")
        req = PathRequest(
            spec=spec, output=out, tenant=meta.tenant, slo=meta.slo,
            deadline_at=deadline_instant(meta), trace=tr,
        )
        self._attach_cache_fill(req.future, key)
        return self._enqueue(req)

    def _solve_path_request(self, req: PathRequest) -> None:
        """Resolve one path request by calling ``repro.select.select_path``
        — literally the offline entry point, with the server's options and
        the admission-resolved output — so the served ``Selection`` (the
        selected graph + per-lambda diagnostics) is bitwise identical to
        the same call made locally."""
        from repro.select import select_path

        try:
            spec = req.spec
            with _req_scope(req):
                # select_path's trace_request degrades to a child span under
                # the request trace — serving owns the root
                selection = select_path(
                    spec.S,
                    X=spec.X,
                    grid=spec.grid,
                    criterion=spec.criterion,
                    n=spec.n,
                    gamma=spec.gamma,
                    options=self.options,
                    stream=spec.stream,
                    output=req.output,
                    criterion_opts=spec.criterion_opts,
                )
            req.future.set_result(selection)
        except Exception as e:
            if not req.future.done():
                req.future.set_exception(e)

    def _solve_joint_request(self, req: JointRequest) -> None:
        """Solve one planned joint request through the shared JointEngine
        (compiled cache process-global, keys carry K — steady-state joint
        traffic compiles nothing)."""
        from repro.joint.api import _joint_result

        try:
            engine = self._joint_engine()
            with _req_scope(req):
                t0 = time.perf_counter()
                Theta, fallbacks = engine.solve_plan(
                    req.plan, req.Ss, output=req.output
                )
                seconds = time.perf_counter() - t0
                req.future.set_result(
                    _joint_result(
                        req.plan, req.labels, req.stats, Theta, seconds,
                        "joint_admm", routed=self.route, fallbacks=fallbacks,
                        assemble_seconds=engine.last_assemble_seconds,
                    )
                )
        except Exception as e:
            if not req.future.done():
                req.future.set_exception(e)

    def metrics(self) -> str:
        """The serving /metrics surface: Prometheus text exposition of the
        process-global ``repro.obs`` registry — every flat counter
        ``serve_stats()`` reports (dots sanitized to underscores) plus the
        labeled ``serve.request_seconds`` histogram, whose ``_bucket`` /
        ``_sum`` / ``_count`` series give any scraper (or
        ``REGISTRY.quantile``) per-tenant/SLO/kind p50/p99 server-side."""
        from repro.obs.metrics import render_prometheus

        return render_prometheus()

    def append_rows(self, session: str, Y: np.ndarray) -> Future:
        """Absorb k new data rows into a named session and re-solve.

        The re-screen is INCREMENTAL (``stream.DataSession``): only tiles
        whose perturbation certificate broke are recomputed
        (``stream.tiles_rescreened`` vs ``stream.tiles_revalidated``),
        affected components merge/split, blocks re-materialize exactly from
        the updated X.  The solve runs synchronously on the caller's thread
        — updates are latency-sensitive and warm-start from the session's
        previous solution (all surviving components begin essentially
        converged), so they never wait out the batching window."""
        from repro.core.solvers import WARM_START_SOLVERS
        from repro.engine.api import _result, blockwise_inverse
        from repro.engine.planner import build_plan_incremental

        with self._sessions_lock:
            entry = self._sessions.get(session)
        if entry is None:
            raise KeyError(
                f"unknown data session {session!r}; open one with "
                "submit(DataSpec(X, lam, session=...))"
            )
        bump("serve.session_updates")
        tr = (
            Trace(
                "serve.request", tenant="default", slo="interactive",
                kind="session", session=session,
            )
            if self.options.trace
            else None
        )
        t_admit = time.perf_counter()
        fut: Future = Future()
        if tr is not None:
            fut.trace = tr
        scope = activate((tr, tr.root_id)) if tr is not None else nullcontext()
        # appends on one session are a serial history
        with entry.lock, scope:
            try:
                prev = None
                if (
                    entry.last is not None
                    and entry.last.done()
                    and entry.last.exception() is None
                ):
                    prev = entry.last.result()
                up = entry.session.append_rows(Y)
                plan, _ = build_plan_incremental(
                    up.S, entry.session.lam, up.labels,
                    classify_structures=self.route, oversize=self.oversize,
                )
                warm_W = None
                if prev is not None and self.solver in WARM_START_SOLVERS:
                    # warm-start only the iterative-routed buckets (same
                    # restriction as the engine path): inverting an OVERSIZE
                    # block on the host would cost exactly the O(b^3) memory/
                    # compute the sharded route exists to avoid — and the
                    # sharded dispatch ignores warm_W anyway
                    from repro.engine.registry import route_for

                    needed = np.zeros(up.S.shape[0], dtype=bool)
                    for b in plan.buckets:
                        if not self.route or route_for(b.structure) == "iterative":
                            for c in b.comps:
                                needed[c] = True
                    if self.oversize is not None and needed.any():
                        # a split can hand an old giant's vertex to a small
                        # new bucket; blockwise_inverse works on the OLD
                        # partition, so old oversize components stay excluded
                        from repro.core.components import component_lists

                        for comp in component_lists(prev.labels):
                            if comp.size > self.oversize:
                                needed[comp] = False
                    if needed.any():
                        warm_W = blockwise_inverse(
                            prev.labels, prev.Theta, needed
                        )
                out_mode = self._resolve_output(None, int(up.S.shape[0]))
                t0 = time.perf_counter()
                Theta = self._session_executor.solve_plan(
                    plan, entry.session.lam, up.S, warm_W=warm_W,
                    output=out_mode,
                )
                seconds = time.perf_counter() - t0
                fut.set_result(
                    _result(
                        plan, up.labels, up.stats, Theta, seconds, self.solver,
                        entry.session.lam, routed=self.route,
                        oversize=self._session_executor.last_oversize,
                        assemble_seconds=(
                            self._session_executor.last_assemble_seconds
                        ),
                    )
                )
            except Exception as e:
                fut.set_exception(e)
            entry.last = fut
        if tr is not None:
            tr.finish()
        REGISTRY.observe(
            "serve.request_seconds",
            time.perf_counter() - t_admit,
            tenant="default", slo="interactive", kind="session",
        )
        return fut

    def _try_fast_path(self, req: GlassoRequest) -> bool:
        """Solve entirely-fast-path requests at admission, skipping the
        dispatch queue.

        Screens and plans on the caller's thread (cheap, O(p^2)); if every
        bucket ROUTES non-iteratively (``registry.route_for``, so
        ``set_route`` re-routing is honored), the ladder executor solves it
        synchronously — including the rare KKT-fallback re-dispatch — and
        the future resolves with zero queueing delay.  Returns False
        (request not handled) when any bucket needs the iterative solver;
        the screen/plan results are stashed on the request so the batcher
        does not redo them."""
        from repro.core.screening import thresholded_components
        from repro.engine.planner import build_plan_incremental

        try:
            with span("serve.plan"):
                labels, stats = thresholded_components(
                    req.S, req.lam, backend=self.cc_backend
                )
                plan, _ = build_plan_incremental(
                    req.S, req.lam, labels, oversize=self.oversize
                )
            req.labels, req.stats, req.plan = labels, stats, plan
            return self._solve_if_fastpath(req)
        except Exception as e:  # pragma: no cover - defensive
            req.future.set_exception(e)
            return True

    def _solve_if_fastpath(self, req: GlassoRequest) -> bool:
        """Admission-time synchronous solve of an already-planned request
        whose every bucket routes non-iteratively; False = needs the queue."""
        from repro.engine.api import _result
        from repro.engine.registry import route_for

        if any(
            route_for(b.structure) in ("iterative", "sharded")
            for b in req.plan.buckets
        ):
            # sharded blocks are mesh-wide blocking solves — never admission-
            # synchronous; they queue for the batcher like iterative work
            return False
        t0 = time.perf_counter()
        Theta = self._fast_executor.solve_plan(
            req.plan, req.lam, req.S, output=req.output
        )
        seconds = time.perf_counter() - t0
        bump("serve.fastpath_requests")
        bump(
            "serve.fastpath_blocks",
            int(
                len(req.plan.isolated)
                + sum(len(b.comps) for b in req.plan.buckets)
            ),
        )
        req.future.set_result(
            _result(
                req.plan, req.labels, req.stats, Theta, seconds, self.solver,
                req.lam, routed=True,
                assemble_seconds=self._fast_executor.last_assemble_seconds,
            )
        )
        return True

    # -- batcher -----------------------------------------------------------

    def _drain(self) -> list[GlassoRequest]:
        try:
            first = self._queue.get(timeout=0.05)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_delay
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _expire(self, batch: list) -> list:
        """Deadline propagation: drop expired requests BEFORE dispatch —
        a dead request never reaches ``solve_batch``."""
        now = time.monotonic()
        live = []
        for req in batch:
            if req.deadline_at is not None and now >= req.deadline_at:
                bump("serve.rejected.deadline")
                if not req.future.done():
                    req.future.set_exception(
                        DeadlineExceeded(
                            f"deadline expired before dispatch "
                            f"(tenant={req.tenant!r})"
                        )
                    )
            else:
                live.append(req)
        return live

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._expire(self._drain())
            if not batch:
                continue
            # strict SLO ordering: the interactive sub-batch dispatches
            # first (batch-class work trades its coalescing opportunity for
            # the interactive class's latency — the queue already dequeues
            # interactive first, this keeps a mixed drain honest too)
            interactive = [r for r in batch if r.slo == "interactive"]
            best_effort = [r for r in batch if r.slo != "interactive"]
            for sub in (interactive, best_effort):
                if not sub:
                    continue
                try:
                    self.solve_batch(sub)
                except Exception as e:  # pragma: no cover - defensive
                    for req in sub:
                        if not req.future.done():
                            req.future.set_exception(e)

    # -- the coalescing solve (callable synchronously too) -----------------

    def solve_batch(self, requests: list[GlassoRequest]) -> None:
        """Screen+plan each request, coalesce same-size buckets across ALL
        requests into one solver dispatch per (padded size, route), scatter
        back.  Closed-form groups carry their KKT flags through the same
        verify-then-iterative-fallback contract as the engine executor.
        Groups containing an interactive request dispatch first (the queue
        and drain loop already order whole batches; this orders the
        dispatches inside one)."""
        import jax
        import jax.numpy as jnp

        from repro.core import blocks as blocks_mod
        from repro.core.screening import thresholded_components
        from repro.engine.api import _result
        from repro.engine.executor import (
            compiled_bucket_solver,
            compiled_closed_form,
            dispatch_repair,
            solve_chordal_bucket,
            solve_sharded_bucket,
        )
        from repro.engine.planner import build_plan_incremental
        from repro.engine.registry import route_for

        t0 = time.perf_counter()
        # joint requests ride the same queue but their buckets carry the K
        # class axis: each is solved through the shared JointEngine (whose
        # dispatches hit the same process-global compiled cache, keyed with
        # K), then the plain requests coalesce as before.  Path requests
        # (PathSpec) resolve whole selection grids through repro.select —
        # their per-lambda bucket dispatches reuse the same process-global
        # compiled cache, so they share executables with the batch even
        # though they do not coalesce into it.
        path_reqs = [r for r in requests if isinstance(r, PathRequest)]
        joint_reqs = [r for r in requests if isinstance(r, JointRequest)]
        requests = [
            r for r in requests if not isinstance(r, (JointRequest, PathRequest))
        ]
        for pr in path_reqs:
            self._solve_path_request(pr)
        for jr in joint_reqs:
            self._solve_joint_request(jr)
        if not requests:
            if joint_reqs or path_reqs:
                bump("serve.batches")
            return
        per_req: list[tuple[GlassoRequest, np.ndarray, object, object]] = []
        groups: dict[tuple[int, str], list[_PlacedBucket]] = {}
        for req in requests:
            if req.plan is not None:  # planned at fast-path admission
                labels, stats, plan = req.labels, req.stats, req.plan
            else:
                with _req_scope(req), span("serve.plan"):
                    labels, stats = thresholded_components(
                        req.S, req.lam, backend=self.cc_backend
                    )
                    plan, _ = build_plan_incremental(
                        req.S, req.lam, labels, classify_structures=self.route,
                        oversize=self.oversize,
                    )
            per_req.append((req, labels, stats, plan))
            for bucket in plan.buckets:
                route = route_for(bucket.structure) if self.route else "iterative"
                groups.setdefault((bucket.size, route), []).append(
                    _PlacedBucket(request=req, plan=plan, bucket=bucket)
                )

        bump("serve.batches")

        def _group_priority(item):
            gkey, placed = item
            interactive = any(
                pb.request.slo == "interactive" for pb in placed
            )
            return (0 if interactive else 1,) + gkey

        # one dispatch per (padded size, route), blocks + per-block lambda
        # stacked across requests; all dispatched before any blocking
        outs: dict[tuple[int, str], object] = {}
        oks: dict[tuple[int, str], object] = {}
        oversize_by_req: dict[int, dict] = {}
        for (size, route), placed in sorted(
            groups.items(), key=_group_priority
        ):
            n_blocks = sum(len(pb.bucket.comps) for pb in placed)
            lams_h = np.concatenate(
                [
                    np.full(len(pb.bucket.comps), pb.request.lam)
                    for pb in placed
                ]
            )
            if route == "sharded":
                # mesh-spanning blocking solves; KKT verification + the
                # single-device fallback happen inside solve_sharded_bucket,
                # so the group carries no ok flags to the repair pass below
                stacks = []
                for pb in placed:
                    n = len(pb.bucket.comps)
                    out_pb, info = solve_sharded_bucket(
                        pb.bucket,
                        np.full(n, pb.request.lam),
                        pb.request.S,
                        solver=self.solver,
                        dtype=self.dtype,
                        opts_key=self._opts_key,
                        tol=self.route_check_tol,
                    )
                    stacks.append(out_pb)
                    acc = oversize_by_req.setdefault(
                        id(pb.request),
                        {"dispatched": 0, "inner_iters": 0, "fallbacks": 0},
                    )
                    for k in acc:
                        acc[k] += info[k]
                outs[(size, route)] = np.concatenate(stacks)
                bump("serve.dispatches")
                n_reqs = len({id(pb.request) for pb in placed})
                if n_reqs > 1:
                    bump("serve.coalesced_blocks", n_blocks)
                continue
            if route == "chordal":
                solved = [
                    timed_dispatch(
                        solve_chordal_bucket,
                        pb.bucket,
                        np.full(len(pb.bucket.comps), pb.request.lam),
                        tol=self.route_check_tol,
                    )[0]
                    for pb in placed
                ]
                outs[(size, route)] = np.concatenate([s[0] for s in solved])
                oks[(size, route)] = np.concatenate([s[1] for s in solved])
                bump("serve.fastpath_blocks", n_blocks)
                bump("serve.dispatches")  # one solver group, host-executed
            else:
                stacked = jnp.concatenate(
                    [jnp.asarray(pb.bucket.blocks, self.dtype) for pb in placed]
                )
                lams = jnp.asarray(lams_h, self.dtype)
                if route == "closed_form":
                    fn = compiled_closed_form(
                        size,
                        self.dtype,
                        tol=self.route_check_tol,
                        verify=any(
                            pb.bucket.structure != "pair" for pb in placed
                        ),
                    )
                    (theta, ok), _ = timed_dispatch(fn, stacked, lams)
                    outs[(size, route)] = theta
                    oks[(size, route)] = ok
                    bump("serve.fastpath_blocks", n_blocks)
                else:
                    fn = compiled_bucket_solver(
                        self.solver,
                        size,
                        self.dtype,
                        warm=False,
                        opts_key=self._opts_key,
                    )
                    outs[(size, route)], _ = timed_dispatch(
                        fn, stacked, lams
                    )
                bump("serve.dispatches")
            n_reqs = len({id(pb.request) for pb in placed})
            if n_reqs > 1:
                bump("serve.coalesced_blocks", n_blocks)
        jax.block_until_ready(
            [v for v in outs.values() if isinstance(v, jax.Array)]
        )

        # verify fast-path groups; repair failures via the shared iterative
        # repair (warm-started from the rejected candidates, same as the
        # engine executor) — only the failed rows are gathered
        for gkey, ok in sorted(oks.items()):
            okh = np.asarray(ok)
            if okh.all():
                continue
            size, _ = gkey
            idx = np.flatnonzero(~okh)
            bump("serve.fallback_blocks", int(idx.size))
            rows = [
                (pb, i)
                for pb in groups[gkey]
                for i in range(len(pb.bucket.comps))
            ]
            blocks_failed = np.stack(
                [np.asarray(rows[k][0].bucket.blocks)[rows[k][1]] for k in idx]
            )
            lams_failed = np.array([rows[k][0].request.lam for k in idx])
            fixed = dispatch_repair(
                self.solver,
                self.dtype,
                self._opts_key,
                size,
                blocks_failed,
                lams_failed,
                np.asarray(outs[gkey])[idx],
            )
            out = np.array(outs[gkey])  # copy: jax arrays view as read-only
            out[idx] = np.asarray(fixed)
            outs[gkey] = out

        # scatter solutions back per bucket (stacks are in `placed` order)
        sols_by_bucket: dict[int, np.ndarray] = {}
        for gkey, placed in sorted(groups.items()):
            sols = np.asarray(outs[gkey])
            k = 0
            for pb in placed:
                n = len(pb.bucket.comps)
                sols_by_bucket[id(pb.bucket)] = sols[k : k + n]
                k += n

        seconds = time.perf_counter() - t0
        # attribute batch wall time to requests by their b^3 solve-cost share
        # (a request's solve_seconds should not count its co-travellers)
        costs = {
            id(req): sum(
                float(len(c)) ** 3 for b in plan.buckets for c in b.comps
            )
            for req, _, _, plan in per_req
        }
        total_cost = sum(costs.values())
        for req, labels, stats, plan in per_req:
            # per-request trace scope: the coalesced dispatches above served
            # MANY requests and stay unattributed (module docstring); only
            # this request's own assembly lands in its span tree, and
            # _result's current_trace() attaches the trace to the result
            with _req_scope(req), span("serve.assemble", output=req.output):
                bucket_sols = [sols_by_bucket[id(b)] for b in plan.buckets]
                ta = time.perf_counter()
                if req.output == "sparse":
                    Theta = blocks_mod.assemble_sparse(plan, bucket_sols, req.S)
                else:
                    Theta = blocks_mod.assemble_dense(plan, bucket_sols, req.S)
                assemble_seconds = time.perf_counter() - ta
                bump("engine.assemble_us", int(assemble_seconds * 1e6))
                share = (
                    costs[id(req)] / total_cost
                    if total_cost > 0
                    else 1.0 / len(per_req)
                )
                req.future.set_result(
                    _result(
                        plan, labels, stats, Theta,
                        seconds * share + assemble_seconds, self.solver,
                        req.lam, routed=self.route,
                        oversize=oversize_by_req.get(id(req)),
                        assemble_seconds=assemble_seconds,
                    )
                )


def serve_stats() -> dict[str, int | float]:
    """Every counter namespace behind the serving surface, in one view —
    the complete table (sum vs peak semantics included) lives in DESIGN.md
    Section 17.  Typed ``int | float``: watermark/derived entries record
    maxima or ratios rather than event sums and are not guaranteed
    integral, so consumers must not assume ``int``."""
    return {
        **counts("serve."),
        **counts("stream."),
        **counts("solver.oversize."),
        **counts("solver.fused."),
        **counts("joint."),
        **counts("select."),
        **counts("engine."),
        **counts("result."),
    }


# ---------------------------------------------------------------------------
# CLI demo: N synthetic concurrent clients
# ---------------------------------------------------------------------------


def main():
    import jax

    jax.config.update("jax_enable_x64", True)

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--p", type=int, default=60)
    ap.add_argument("--blocks", type=int, default=5)
    ap.add_argument("--solver", default="bcd")
    args = ap.parse_args()

    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.executor import compiled_cache_stats
    from repro.engine.options import EngineOptions

    reqs = []
    for i in range(args.requests):
        S = paper_synthetic(args.blocks, args.p // args.blocks, seed=i)
        lam_min, lam_max = lambda_interval_for_k(S, args.blocks)
        reqs.append((S, 0.5 * (lam_min + lam_max)))

    options = EngineOptions(solver=args.solver, solver_opts={"tol": 1e-7})
    with GlassoServer(options=options) as server:
        t0 = time.perf_counter()
        futures = [
            server.submit(DenseSpec(S, lam), meta=RequestMeta(tenant="demo"))
            for S, lam in reqs
        ]
        results = [f.result(timeout=600) for f in futures]
        dt = time.perf_counter() - t0

    for i, r in enumerate(results):
        print(
            f"req {i}: lam={r.lam:.4f} comps={r.screen.n_components} "
            f"blocks={r.block_sizes}"
        )
    print(f"{len(results)} requests in {dt:.2f}s ({len(results)/dt:.1f} req/s)")
    print("serve counters:", serve_stats())
    print("compiled cache:", compiled_cache_stats())
    # the /metrics surface: show the labeled latency histogram summary lines
    # (full exposition = GlassoServer.metrics(); registry is process-global,
    # so reading it after stop() is fine)
    hist = [
        ln
        for ln in server.metrics().splitlines()
        if ln.startswith("serve_request_seconds_")
        and ("_sum{" in ln or "_count{" in ln)
    ]
    print("metrics (serve_request_seconds):")
    for ln in hist:
        print(" ", ln)
    if results and results[0].trace is not None:
        print(
            "trace (req 0):",
            {k: round(v, 6) for k, v in results[0].trace.stage_seconds().items()},
        )


if __name__ == "__main__":
    main()

"""Batched graphical-lasso serving: many concurrent (S, lam) requests, one
coalesced solver stream — the ROADMAP's "heavy traffic" workload for the
Theorem-1 pipeline.

Theorem 1 makes every request a bag of INDEPENDENT padded blocks, and the
engine's executor already batches same-size blocks; serving just widens the
batch axis across requests.  The batcher thread drains the queue, screens and
plans each request through the engine registry/planner, then regroups every
(request, bucket) by padded size and dispatches ONE compiled solver call per
size with a per-block lambda vector — so requests with different lambdas, or
different matrices, share executables AND batches.  The compiled cache is the
executor's process-global one: after warm-up, a steady-state mix of request
shapes runs with zero compiles (watch ``executor.compiled_hit``).

    PYTHONPATH=src python -m repro.launch.serve_glasso --requests 8 --p 60

Counters (repro.core.instrument):
    serve.requests            requests admitted
    serve.batches             batcher iterations that dispatched work
    serve.dispatches          coalesced solver calls (one per padded size)
    serve.coalesced_blocks    blocks that shared a call with ANOTHER request
"""

from __future__ import annotations

import argparse
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.instrument import bump, counts


@dataclass
class GlassoRequest:
    S: np.ndarray
    lam: float
    future: Future = field(default_factory=Future)


@dataclass
class _PlacedBucket:
    request: "GlassoRequest"
    plan: object
    bucket: object


class GlassoServer:
    """Coalescing batch server over the engine executor.

    ``submit`` is thread-safe and returns a Future resolving to the engine's
    ``GlassoResult``.  ``max_delay`` is the batching window: the batcher waits
    that long after the first queued request for co-travellers before
    dispatching (classic serving latency/throughput knob)."""

    def __init__(
        self,
        *,
        solver: str = "bcd",
        dtype=None,
        cc_backend: str = "host",
        max_delay: float = 0.005,
        max_batch: int = 64,
        **solver_opts,
    ):
        import jax.numpy as jnp

        from repro.core.solvers import SOLVERS
        from repro.engine.executor import _validate_solver_opts

        if solver not in SOLVERS:
            raise ValueError(
                f"unknown solver {solver!r}; available: {sorted(SOLVERS)}"
            )
        _validate_solver_opts(solver, solver_opts)
        self.solver = solver
        self.dtype = jnp.float64 if dtype is None else dtype
        self.cc_backend = cc_backend
        self.max_delay = max_delay
        self.max_batch = max_batch
        self.solver_opts = solver_opts
        self._opts_key = tuple(sorted(solver_opts.items()))
        self._queue: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "GlassoServer":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
        self._fail_pending()

    def _fail_pending(self) -> None:
        """Fail queued requests fast instead of letting their clients block
        out the full result() timeout.  Called from stop() and from submit()
        when it loses the shutdown race."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if not req.future.done():
                req.future.set_exception(RuntimeError("GlassoServer stopped"))

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, S: np.ndarray, lam: float) -> Future:
        req = GlassoRequest(S=np.asarray(S), lam=float(lam))
        if self._stop.is_set():
            # fail fast instead of parking a request no batcher will serve
            req.future.set_exception(RuntimeError("GlassoServer stopped"))
            return req.future
        bump("serve.requests")
        self._queue.put(req)
        if self._stop.is_set():
            # lost the race against stop(): its drain may have run before our
            # put landed, so sweep the queue ourselves
            self._fail_pending()
        return req.future

    # -- batcher -----------------------------------------------------------

    def _drain(self) -> list[GlassoRequest]:
        try:
            first = self._queue.get(timeout=0.05)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_delay
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._drain()
            if not batch:
                continue
            try:
                self.solve_batch(batch)
            except Exception as e:  # pragma: no cover - defensive
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(e)

    # -- the coalescing solve (callable synchronously too) -----------------

    def solve_batch(self, requests: list[GlassoRequest]) -> None:
        """Screen+plan each request, coalesce same-size buckets across ALL
        requests into one solver dispatch per padded size, scatter back."""
        import jax
        import jax.numpy as jnp

        from repro.core import blocks as blocks_mod
        from repro.core.screening import thresholded_components
        from repro.engine.api import _result
        from repro.engine.executor import compiled_bucket_solver
        from repro.engine.planner import build_plan_incremental

        t0 = time.perf_counter()
        per_req: list[tuple[GlassoRequest, np.ndarray, object, object]] = []
        by_size: dict[int, list[_PlacedBucket]] = {}
        for req in requests:
            labels, stats = thresholded_components(
                req.S, req.lam, backend=self.cc_backend
            )
            plan, _ = build_plan_incremental(req.S, req.lam, labels)
            per_req.append((req, labels, stats, plan))
            for bucket in plan.buckets:
                by_size.setdefault(bucket.size, []).append(
                    _PlacedBucket(request=req, plan=plan, bucket=bucket)
                )

        bump("serve.batches")
        # one dispatch per padded size, blocks + per-block lambda stacked
        # across requests; all dispatched before any blocking
        outs: dict[int, object] = {}
        for size, placed in sorted(by_size.items()):
            stacked = jnp.concatenate(
                [jnp.asarray(pb.bucket.blocks, self.dtype) for pb in placed]
            )
            lams = jnp.concatenate(
                [
                    jnp.full((pb.bucket.blocks.shape[0],), pb.request.lam, self.dtype)
                    for pb in placed
                ]
            )
            fn = compiled_bucket_solver(
                self.solver, size, self.dtype, warm=False, opts_key=self._opts_key
            )
            outs[size] = fn(stacked, lams)
            bump("serve.dispatches")
            n_reqs = len({id(pb.request) for pb in placed})
            if n_reqs > 1:
                bump("serve.coalesced_blocks", int(stacked.shape[0]))
        jax.block_until_ready(list(outs.values()))

        # scatter solutions back per request
        cursors = {size: 0 for size in outs}
        sols_by_req: dict[int, dict[int, list]] = {}
        for size, placed in sorted(by_size.items()):
            sols = np.asarray(outs[size])
            for pb in placed:
                n = pb.bucket.blocks.shape[0]
                k = cursors[size]
                sols_by_req.setdefault(id(pb.request), {}).setdefault(
                    size, []
                ).append(sols[k : k + n])
                cursors[size] = k + n

        seconds = time.perf_counter() - t0
        # attribute batch wall time to requests by their b^3 solve-cost share
        # (a request's solve_seconds should not count its co-travellers)
        costs = {
            id(req): sum(
                float(len(c)) ** 3 for b in plan.buckets for c in b.comps
            )
            for req, _, _, plan in per_req
        }
        total_cost = sum(costs.values())
        for req, labels, stats, plan in per_req:
            chunks = sols_by_req.get(id(req), {})
            bucket_sols = [chunks[b.size].pop(0) for b in plan.buckets]
            Theta = blocks_mod.assemble_dense(plan, bucket_sols, req.S)
            share = costs[id(req)] / total_cost if total_cost > 0 else 1.0 / len(per_req)
            req.future.set_result(
                _result(plan, labels, stats, Theta, seconds * share, self.solver, req.lam)
            )


def serve_stats() -> dict[str, int]:
    return counts("serve.")


# ---------------------------------------------------------------------------
# CLI demo: N synthetic concurrent clients
# ---------------------------------------------------------------------------


def main():
    import jax

    jax.config.update("jax_enable_x64", True)

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--p", type=int, default=60)
    ap.add_argument("--blocks", type=int, default=5)
    ap.add_argument("--solver", default="bcd")
    args = ap.parse_args()

    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.executor import compiled_cache_stats

    reqs = []
    for i in range(args.requests):
        S = paper_synthetic(args.blocks, args.p // args.blocks, seed=i)
        lam_min, lam_max = lambda_interval_for_k(S, args.blocks)
        reqs.append((S, 0.5 * (lam_min + lam_max)))

    with GlassoServer(solver=args.solver, tol=1e-7) as server:
        t0 = time.perf_counter()
        futures = [server.submit(S, lam) for S, lam in reqs]
        results = [f.result(timeout=600) for f in futures]
        dt = time.perf_counter() - t0

    for i, r in enumerate(results):
        print(
            f"req {i}: lam={r.lam:.4f} comps={r.screen.n_components} "
            f"blocks={r.block_sizes}"
        )
    print(f"{len(results)} requests in {dt:.2f}s ({len(results)/dt:.1f} req/s)")
    print("serve counters:", serve_stats())
    print("compiled cache:", compiled_cache_stats())


if __name__ == "__main__":
    main()

"""Serving driver: batched prefill + greedy decode on a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def run_serving(*, arch: str, batch: int, prompt_len: int, new_tokens: int,
                reduced: bool = True, seed: int = 0, log=print):
    from repro.configs.base import ShapeConfig, get_arch
    from repro.data.specs import make_batch
    from repro.models.zoo import build_model, count_params
    from repro.train.serving import greedy_generate

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(seed))
    log(f"arch={cfg.name} params={count_params(params):,}")

    shape = ShapeConfig("serve_cli", seq_len=prompt_len, global_batch=batch, kind="prefill")
    batch_data = make_batch(cfg, shape, seed=seed)

    t0 = time.perf_counter()
    tokens = greedy_generate(model, params, batch_data, max_new_tokens=new_tokens)
    dt = time.perf_counter() - t0
    total_new = batch * new_tokens
    log(f"generated {tokens.shape} in {dt:.2f}s  ({total_new/dt:.1f} tok/s incl. prefill+compile)")
    return np.asarray(tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run_serving(arch=args.arch, batch=args.batch, prompt_len=args.prompt_len,
                new_tokens=args.new_tokens, reduced=not args.full)


if __name__ == "__main__":
    main()

"""Regenerate EXPERIMENTS.md from the dry-run records + static narrative.

    PYTHONPATH=src python scripts/gen_experiments.py
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.roofline import load_records, markdown_table, roofline_row  # noqa: E402

GiB = 1024**3


def dryrun_section() -> str:
    base = [roofline_row(r) for r in load_records("")]
    opt = {
        (r["arch"], r["shape"], r["mesh"]): roofline_row(r)
        for r in load_records("xlaflash")
    }
    ok = [r for r in base if r["status"] == "ok"]
    lines = [
        "## §Dry-run\n",
        f"\n{len(base)} cells = 10 archs x 4 shapes x 2 meshes; "
        f"**{len(ok)} compiled ok**, "
        f"{sum(1 for r in base if r['status'] == 'skipped')} skipped "
        "(documented long_500k skips for the 8 pure full-attention archs), "
        "0 errors.  Every ok cell printed `compiled.memory_analysis()` and "
        "`cost_analysis()`; raw records in `experiments/dryrun/*.json`.\n",
        "\nPer-device memory (argument+temp bytes, HBM budget 16 GiB/chip) — "
        "**optimized** configuration (xlaflash tag; see §Perf):\n\n",
        "| arch | shape | mesh | args GiB | temp GiB | fits 16 GiB |\n"
        "|---|---|---|---|---|---|\n",
    ]
    rows = sorted(opt.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    for r in rows:
        if r["status"] != "ok":
            continue
        a = r["memory"]["argument_bytes"] / GiB
        t = r["memory"]["temp_bytes"] / GiB
        fits = "yes" if a + t <= 16.0 else f"NO ({a+t:.1f})"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {a:.2f} | {t:.2f} | {fits} |\n"
        )
    lines.append(
        "\nCollective schedule (per-device bytes by op, summed over the step; "
        "single-pod, train_4k, optimized):\n\n"
        "| arch | all-gather | all-reduce | reduce-scatter | all-to-all | permute |\n"
        "|---|---|---|---|---|---|\n"
    )
    for r in rows:
        if r["status"] != "ok" or r["shape"] != "train_4k" or r["mesh"] != "single":
            continue
        c = r["collective"]
        lines.append(
            f"| {r['arch']} | {c.get('all-gather', 0):.2e} | {c.get('all-reduce', 0):.2e} "
            f"| {c.get('reduce-scatter', 0):.2e} | {c.get('all-to-all', 0):.2e} "
            f"| {c.get('collective-permute', 0):.2e} |\n"
        )
    return "".join(lines)


def roofline_section() -> str:
    base = [roofline_row(r) for r in load_records("")]
    opt = [roofline_row(r) for r in load_records("xlaflash")]
    base.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    opt.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["## §Roofline\n\n"]
    out.append(Path(ROOT / "docs" / "roofline_method.md").read_text())
    out.append("\n### Baseline (paper-faithful substrate, pre-optimization) — single pod\n\n")
    out.append(markdown_table([r for r in base if r["mesh"] == "single"]))
    out.append("\n### Optimized (post §Perf iterations) — single pod\n\n")
    out.append(markdown_table([r for r in opt if r["mesh"] == "single"]))
    out.append("\n### Optimized — multi-pod (2 x 16 x 16 = 512 chips)\n\n")
    out.append(markdown_table([r for r in opt if r["mesh"] == "multi"]))
    return "".join(out)


def main():
    tpl = (ROOT / "docs" / "experiments_narrative.md").read_text()
    doc = tpl.replace("<!--DRYRUN-->", dryrun_section()).replace(
        "<!--ROOFLINE-->", roofline_section()
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
